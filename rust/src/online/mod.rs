//! `online` — closed-loop adaptive format routing for the serving pool.
//!
//! The run-time mode of the paper trains its format classifier once,
//! offline, on the §5 sweep; under sustained traffic the matrix
//! population drifts away from that corpus and a frozen router silently
//! keeps routing to stale formats. This subsystem closes the loop
//! (observe -> explore -> retrain -> hot-swap):
//!
//! * **Observe** ([`observer`]): every executed dispatch streams an
//!   [`Observation`] — features, the (format, compile-knob) decision
//!   actually run, measured execution latency, gpusim-modeled energy —
//!   into a bounded drop-oldest buffer.
//! * **Explore** ([`bandit`]): a per-feature-bucket epsilon-greedy
//!   explorer occasionally routes a dispatch to a *non-predicted*
//!   joint arm (another format, or another compile knob of the same
//!   format) so the buffer holds counterfactual labels; arm choice is
//!   count-balanced until the per-arm UCB floor. Deterministic given
//!   the seed; zero overhead (and zero RNG draws) at rate 0.
//! * **Retrain** ([`trainer`]): a retraining task periodically fits a
//!   fresh `RunTimeOptimizer` AND a per-format `KnobPolicy` on offline
//!   + accumulated online evidence through the existing training paths.
//! * **Hot-swap** ([`router`]): a versioned `RwLock<Arc<Policy>>`
//!   handle the shards poll with one atomic load; on an upgrade each
//!   shard re-decides its registered matrices so they can migrate
//!   formats AND compile knobs (re-selected artifacts, re-prepared
//!   literals).
//! * **Drift** ([`drift`]): a windowed mean/variance shift detector
//!   over the Table-2 features triggers retraining early and is
//!   surfaced in `PoolStats`.
//!
//! Exploration and retraining stay entirely off the prepared-literal
//! hot path: the bandit is consulted once per *dispatch* (not per
//! request), observations are one `Mutex` push per dispatch, and
//! retrains run either on a background thread or inline on the shard
//! *between* dispatches — never under a request's execution.

pub mod bandit;
pub mod drift;
pub mod observer;
pub mod router;
pub mod trainer;

pub use bandit::{Bandit, Decision as JointDecision, RouteChoice};
pub use drift::{DriftConfig, DriftDetector, DriftStatus};
pub use observer::{Observation, Observer};
pub use router::{Policy, SwapRouter};
pub use trainer::Trainer;

use crate::coordinator::RunTimeOptimizer;
use crate::features::Features;
use crate::gpusim::Objective;
use crate::obs::{EventKind, SwapTrigger};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

/// Tuning for the closed loop.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Fraction of dispatches routed to a non-predicted format arm
    /// (0 disables exploration entirely — bit-identical to a frozen
    /// pool).
    pub explore_rate: f64,
    /// Retrain after this many newly observed *requests* (a coalesced
    /// dispatch counts its batch size). 0 disables retraining; drift
    /// can still be *observed* but never triggers.
    pub retrain_every: u64,
    /// Seed for the exploration schedule.
    pub seed: u64,
    /// Auto-anneal exploration: once every alternative format in a
    /// feature bucket has this many credited observations (summed
    /// across its knob arms), that bucket's effective explore rate
    /// reaches 0 (linear decay with the weakest format's evidence).
    /// `None` keeps the rate flat. Per-bucket, so drifted-in matrix
    /// populations still explore at full rate.
    pub anneal_target: Option<u64>,
    /// Decide compile knobs jointly with the format: the bandit
    /// explores knob arms and every retrain installs a per-format
    /// [`crate::coordinator::compile_time::KnobPolicy`] next to the
    /// format router. `false` reproduces the PR 2/3 format-only loop.
    pub joint_knobs: bool,
    /// Evidence floor at which exploration switches from
    /// count-balancing to per-arm UCB scoring (0 = count-balance
    /// forever). Credited like `anneal_target`: per alternative format,
    /// knob arms summed — keep it below the anneal target so UCB
    /// engages while annealing buckets still explore.
    pub ucb_floor: u64,
    /// Observation ring capacity (the retraining window).
    pub buffer_cap: usize,
    /// Drift detector tuning.
    pub drift: DriftConfig,
    /// Run retrains on a dedicated background thread instead of inline
    /// on the shard that crossed the threshold. Background mode keeps
    /// serving latency flat during a retrain at the cost of a
    /// nondeterministic swap point; tests use inline mode.
    pub background: bool,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            explore_rate: 0.05,
            retrain_every: 0,
            seed: 0xC10_5ED,
            anneal_target: None,
            joint_knobs: true,
            ucb_floor: bandit::DEFAULT_UCB_FLOOR,
            buffer_cap: 4096,
            drift: DriftConfig::default(),
            background: false,
        }
    }
}

/// The closed-loop state shared by the pool's shards and the trainer.
pub struct Online {
    cfg: OnlineConfig,
    objective: Objective,
    /// The hot-swappable router handle (shards poll its version).
    pub router: Arc<SwapRouter>,
    bandit: Bandit,
    observer: Observer,
    drift: DriftDetector,
    trainer: Option<Trainer>,
    /// Serializes retrains (threshold crossings race across shards).
    retrain_lock: Mutex<()>,
    /// Observation total at the last retrain (cadence bookkeeping).
    last_retrain_total: AtomicU64,
    retrains: AtomicU64,
    /// Nudge channel to the background trainer thread (None inline).
    nudge: Mutex<Option<Sender<()>>>,
}

impl Online {
    /// Build the loop around an initial router. Pass a [`Trainer`] to
    /// enable retraining; `None` gives an explore/observe-only loop
    /// (the buffer still fills, e.g. for offline analysis).
    pub fn start(
        cfg: OnlineConfig,
        initial: Arc<RunTimeOptimizer>,
        objective: Objective,
        trainer: Option<Trainer>,
    ) -> Arc<Online> {
        let online = Arc::new(Online {
            bandit: Bandit::with_params(
                cfg.explore_rate,
                cfg.seed,
                cfg.anneal_target,
                cfg.ucb_floor,
                objective.minimize(),
                cfg.joint_knobs,
            ),
            observer: Observer::new(cfg.buffer_cap),
            drift: DriftDetector::new(cfg.drift),
            router: Arc::new(SwapRouter::new(initial)),
            objective,
            trainer,
            retrain_lock: Mutex::new(()),
            last_retrain_total: AtomicU64::new(0),
            retrains: AtomicU64::new(0),
            nudge: Mutex::new(None),
            cfg,
        });
        if online.cfg.background && online.retraining_enabled() {
            let (tx, rx) = channel::<()>();
            *online.nudge.lock().expect("nudge lock") = Some(tx);
            let weak: Weak<Online> = Arc::downgrade(&online);
            std::thread::Builder::new()
                .name("online-trainer".into())
                .spawn(move || {
                    // Exits when every pool/user handle is gone: the
                    // senders live inside `Online`, the thread holds
                    // only a Weak, so `recv` errors out on drop.
                    while rx.recv().is_ok() {
                        while rx.try_recv().is_ok() {} // collapse queued nudges
                        let Some(o) = weak.upgrade() else { break };
                        o.retrain_if_due();
                    }
                })
                .expect("spawn online trainer");
        }
        online
    }

    pub fn config(&self) -> &OnlineConfig {
        &self.cfg
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    fn retraining_enabled(&self) -> bool {
        self.trainer.is_some() && self.cfg.retrain_every > 0
    }

    /// Route one dispatch (shard hot path): the policy's joint
    /// decision, or an exploration arm at the configured rate.
    pub fn route(&self, feats: &Features, decided: JointDecision) -> RouteChoice {
        self.bandit.route(feats, decided)
    }

    /// [`route`](Self::route) for an explicit kernel kind: solves
    /// (SpTRSV / SymGS) explore in kind-qualified buckets so their
    /// evidence never mixes with SpMV's (the kind is part of the
    /// request class).
    pub fn route_kind(
        &self,
        kind: crate::sparse::KernelKind,
        feats: &Features,
        decided: JointDecision,
    ) -> RouteChoice {
        self.bandit.route_kind(kind, feats, decided)
    }

    /// Current exploration rate (live value, not the configured one).
    pub fn explore_rate(&self) -> f64 {
        self.bandit.explore_rate()
    }

    /// Anneal (or pause, with 0) exploration on the live pool. The
    /// observation/retrain loop keeps running either way.
    pub fn set_explore_rate(&self, rate: f64) {
        self.bandit.set_explore_rate(rate);
    }

    /// Feed back one executed dispatch. May trigger a retrain (inline
    /// or via the background thread) when the cadence threshold is
    /// crossed or the drift detector fires.
    pub fn observe(&self, obs: Observation) {
        let value = match self.objective {
            Objective::Latency => obs.measured_latency_s,
            _ => self.objective.value(&obs.modeled),
        };
        self.bandit.observe_kind(
            obs.kind,
            &obs.features,
            JointDecision { format: obs.format, choice: obs.choice },
            value,
        );
        let newly_drifted = self.drift.add(&obs.features);
        if newly_drifted {
            // journal the rising edge with the detector's verdict (the
            // shifted feature and how far it moved, in reference sigmas)
            let status = self.drift.status();
            self.router
                .journal()
                .emit(EventKind::Drift { feature: status.feature, sigma: status.max_shift });
        }
        self.observer.record(obs);
        if !self.retraining_enabled() {
            return;
        }
        if self.due(newly_drifted) {
            if self.cfg.background {
                if let Some(tx) = &*self.nudge.lock().expect("nudge lock") {
                    let _ = tx.send(());
                }
            } else {
                self.retrain_if_due();
            }
        }
    }

    /// Cadence check: enough new requests since the last retrain, or an
    /// unabsorbed drift flag (the detector stays drifted until a
    /// retrain rebases it, so this is safe to re-evaluate).
    fn due(&self, newly_drifted: bool) -> bool {
        let last = self.last_retrain_total.load(Ordering::Acquire);
        let since = self.observer.total().saturating_sub(last);
        since >= self.cfg.retrain_every || newly_drifted || self.drift.status().drifted
    }

    /// Retrain on the current buffer snapshot and hot-swap the router.
    /// Returns the new router version, or `None` when there is no
    /// trainer or nothing observed yet. Safe to call from tests/CLI at
    /// any time; concurrent calls serialize.
    pub fn retrain_now(&self) -> Option<u64> {
        self.retrain_inner(true)
    }

    /// Like [`Self::retrain_now`], but for the cadence path: when
    /// several shards cross the threshold together, the first one takes
    /// the lock and retrains; the rest must NOT convoy behind it (an
    /// inline retrain is a full model refit), so a contended try_lock
    /// returns immediately — the in-flight retrain is already servicing
    /// this threshold crossing. A shard that does win the lock re-checks
    /// the cadence, catching the just-reset counter.
    fn retrain_if_due(&self) -> Option<u64> {
        self.retrain_inner(false)
    }

    fn retrain_inner(&self, force: bool) -> Option<u64> {
        let trainer = self.trainer.as_ref()?;
        let _guard = if force {
            self.retrain_lock.lock().expect("retrain lock")
        } else {
            match self.retrain_lock.try_lock() {
                Ok(guard) => guard,
                Err(std::sync::TryLockError::WouldBlock) => return None,
                Err(std::sync::TryLockError::Poisoned(_)) => panic!("retrain lock poisoned"),
            }
        };
        if !force && !self.due(false) {
            return None;
        }
        let total = self.observer.total();
        let obs = self.observer.snapshot();
        if obs.is_empty() {
            return None;
        }
        // attribute the retrain before rebasing clears the drift flag:
        // an unabsorbed drift wins over the cadence, a direct
        // `retrain_now` with no drift pending is a manual action
        let trigger = if self.drift.status().drifted {
            SwapTrigger::Drift
        } else if force {
            SwapTrigger::Manual
        } else {
            SwapTrigger::Cadence
        };
        let t0 = Instant::now();
        let next = trainer.retrain_with(&obs, self.cfg.joint_knobs);
        let duration = t0.elapsed();
        self.last_retrain_total.store(total, Ordering::Release);
        self.retrains.fetch_add(1, Ordering::Relaxed);
        self.drift.rebase();
        self.router.journal().emit(EventKind::Retrain { examples: obs.len(), duration, trigger });
        // the retrained router + knob policy swap in as ONE policy, so
        // a shard's re-decision pass sees a consistent joint surface
        let policy = if self.cfg.joint_knobs {
            Policy::joint(Arc::new(next.router), Arc::new(next.knobs))
        } else {
            Policy::format_only(Arc::new(next.router))
        };
        Some(self.router.install_policy_traced(Arc::new(policy), trigger))
    }

    /// Completed retrains.
    pub fn retrains(&self) -> u64 {
        self.retrains.load(Ordering::Relaxed)
    }

    /// Checkpoint the observation window as a `dataset::store` TSV so a
    /// pool restart resumes retraining from recent traffic instead of
    /// an empty buffer. Returns the number of observations saved.
    pub fn save_observations(&self, path: &std::path::Path) -> anyhow::Result<usize> {
        let obs = self.observer.snapshot();
        let arch = self.trainer.as_ref().map_or("unknown", |t| t.arch());
        let ds = crate::dataset::Dataset { records: observer::to_records(&obs, arch) };
        crate::dataset::store::save(&ds, path)?;
        Ok(obs.len())
    }

    /// Restore a window saved by [`Online::save_observations`] into the
    /// buffer (oldest first; bounded by the ring capacity as usual).
    /// The restored history seeds the next retrain's window but does
    /// not count as fresh traffic: the retrain cadence rebases so only
    /// post-restore requests trip it. Returns the observations loaded.
    pub fn load_observations(&self, path: &std::path::Path) -> anyhow::Result<usize> {
        let ds = crate::dataset::store::load(path)?;
        let obs = observer::from_records(&ds.records)?;
        let n = obs.len();
        for o in &obs {
            self.observer.record(*o);
        }
        self.last_retrain_total.store(self.observer.total(), Ordering::Release);
        Ok(n)
    }

    /// Total requests observed (batch-weighted: a coalesced dispatch
    /// counts its batch size — the same unit as `retrain_every`).
    pub fn observed_requests(&self) -> u64 {
        self.observer.total()
    }

    pub fn drift_status(&self) -> DriftStatus {
        self.drift.status()
    }

    /// Exploration stats for a feature vector's bucket, joint-arm
    /// order (debug aid).
    pub fn arms(&self, feats: &Features) -> Vec<bandit::ArmStats> {
        self.bandit.arms(feats)
    }

    /// [`arms`](Self::arms) for an explicit kernel kind's bucket.
    pub fn arms_kind(
        &self,
        kind: crate::sparse::KernelKind,
        feats: &Features,
    ) -> Vec<bandit::ArmStats> {
        self.bandit.arms_kind(kind, feats)
    }

    /// Exploration picks made through the per-arm UCB scorer.
    pub fn ucb_routes(&self) -> u64 {
        self.bandit.ucb_routes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::sparse::Format;
    use crate::testutil::toy_setup;
    use std::time::Duration;

    fn obs_for(coo: &crate::sparse::Coo, format: Format, energy: f64) -> Observation {
        let feats = crate::features::extract_coo(coo);
        Observation {
            matrix_id: 0,
            kind: crate::sparse::KernelKind::Spmv,
            features: feats,
            format,
            choice: crate::coordinator::compile_time::CompileChoice::serving_default(),
            explored: false,
            requests: 1,
            measured_latency_s: 1e-6,
            modeled: crate::gpusim::Measurement {
                latency_s: 1e-6,
                energy_j: energy,
                avg_power_w: 1.0,
                mflops_per_watt: 1.0 / energy,
            },
        }
    }

    #[test]
    fn observe_only_loop_never_retrains() {
        let (router, _, _) = toy_setup(&["rim"], Objective::Energy);
        let online = Online::start(
            OnlineConfig { retrain_every: 2, ..Default::default() },
            Arc::new(router),
            Objective::Energy,
            None, // no trainer
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        for _ in 0..10 {
            online.observe(obs_for(&coo, Format::Csr, 1e-4));
        }
        assert_eq!(online.retrains(), 0);
        assert_eq!(online.router.version(), 1);
        assert_eq!(online.observed_requests(), 10);
        assert!(online.retrain_now().is_none());
    }

    #[test]
    fn inline_cadence_retrains_and_bumps_version() {
        let (router, ds, overhead) = toy_setup(&["rim", "eu-2005"], Objective::Energy);
        let trainer = Trainer::new(ds, Objective::Energy, overhead, "GTX1650m-Turing");
        let online = Online::start(
            OnlineConfig { retrain_every: 4, background: false, ..Default::default() },
            Arc::new(router),
            Objective::Energy,
            Some(trainer),
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        for _ in 0..4 {
            online.observe(obs_for(&coo, Format::Csr, 1e-4));
        }
        assert_eq!(online.retrains(), 1, "4th observation crosses the cadence");
        assert_eq!(online.router.version(), 2);
        for _ in 0..3 {
            online.observe(obs_for(&coo, Format::Csr, 1e-4));
        }
        assert_eq!(online.retrains(), 1, "cadence counts from the last retrain");
        online.observe(obs_for(&coo, Format::Csr, 1e-4));
        assert_eq!(online.retrains(), 2);
    }

    #[test]
    fn observation_checkpoint_survives_a_pool_restart() {
        let (router, ds, overhead) = toy_setup(&["rim", "eu-2005"], Objective::Energy);
        let router = Arc::new(router);
        let mk_online = |retrain_every| {
            let trainer =
                Trainer::new(ds.clone(), Objective::Energy, overhead.clone(), "GTX1650m-Turing");
            Online::start(
                OnlineConfig { retrain_every, background: false, ..Default::default() },
                router.clone(),
                Objective::Energy,
                Some(trainer),
            )
        };
        let first = mk_online(0); // observe-only: buffer fills, no swaps
        let coo = gen::by_name("rim").unwrap().generate(1);
        for i in 0..5 {
            let mut o = obs_for(&coo, if i % 2 == 0 { Format::Csr } else { Format::Ell }, 1e-4);
            o.requests = 1 + i as u64;
            o.explored = i % 2 == 1;
            first.observe(o);
        }
        let path = std::env::temp_dir().join("autospmv_obs_ckpt_test.tsv");
        assert_eq!(first.save_observations(&path).unwrap(), 5);

        // "restart": a fresh loop restores the window...
        let second = mk_online(1000);
        assert_eq!(second.load_observations(&path).unwrap(), 5);
        assert_eq!(second.observed_requests(), first.observed_requests());
        let (a, b) = (first.observer.snapshot(), second.observer.snapshot());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.matrix_id, y.matrix_id);
            assert_eq!(x.format, y.format);
            assert_eq!(x.explored, y.explored);
            assert_eq!(x.requests, y.requests);
            assert_eq!(x.measured_latency_s.to_bits(), y.measured_latency_s.to_bits());
            assert_eq!(x.features, y.features);
            assert_eq!(x.modeled, y.modeled);
        }
        // ...the restored history feeds the next retrain...
        assert!(second.retrain_now().is_some(), "restored window must be trainable");
        // ...but does not count as fresh traffic toward the cadence
        let third = mk_online(1000);
        third.load_observations(&path).unwrap();
        third.observe(obs_for(&coo, Format::Csr, 1e-4));
        assert_eq!(third.retrains(), 0, "5 restored + 1 fresh must not cross a cadence of 1000");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn background_mode_retrains_off_thread() {
        let (router, ds, overhead) = toy_setup(&["rim"], Objective::Energy);
        let trainer = Trainer::new(ds, Objective::Energy, overhead, "GTX1650m-Turing");
        let online = Online::start(
            OnlineConfig { retrain_every: 2, background: true, ..Default::default() },
            Arc::new(router),
            Objective::Energy,
            Some(trainer),
        );
        let coo = gen::by_name("rim").unwrap().generate(1);
        for _ in 0..2 {
            online.observe(obs_for(&coo, Format::Csr, 1e-4));
        }
        assert!(
            online.router.wait_for_version(2, Duration::from_secs(30)),
            "background retrain must land"
        );
        assert!(online.retrains() >= 1);
    }
}
