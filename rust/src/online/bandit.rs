//! Per-feature-bucket exploration over `Format` arms.
//!
//! The offline router only ever sees labels for the corpus it was
//! trained on; under workload drift the buffer of online observations
//! would contain nothing but the predicted format's outcomes and the
//! trainer could never learn that another format now wins. The bandit
//! fixes that: with probability `explore_rate` a dispatch is routed to
//! a *non-predicted* arm so the observation buffer holds counterfactual
//! labels. Arm choice is count-balanced within the matrix's feature
//! bucket (the UCB exploration bonus in the limit where unexplored arms
//! dominate): the least-pulled alternative goes first, so all three
//! alternatives get sampled instead of one lucky arm.
//!
//! Everything is deterministic given the seed and the dispatch order:
//! the RNG is the crate's own xoshiro [`Rng`], consulted exactly once
//! per routed dispatch (zero draws when `explore_rate == 0`, which is
//! what makes the frozen-pool bit-identity property hold).

use crate::features::Features;
use crate::gen::Rng;
use crate::sparse::Format;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of format arms (`Format::ALL`).
pub const N_FORMATS: usize = Format::ALL.len();

/// Routing outcome for one dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteChoice {
    /// Format this dispatch executes in.
    pub format: Format,
    /// True when the bandit overrode the router's decision.
    pub explored: bool,
}

impl RouteChoice {
    /// The trivial non-exploring choice.
    pub fn chosen(format: Format) -> RouteChoice {
        RouteChoice { format, explored: false }
    }
}

/// Per-arm statistics inside one feature bucket.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArmStats {
    /// Dispatches routed to this arm (chosen + explored).
    pub pulls: u64,
    /// Observations credited to this arm.
    pub observations: u64,
    /// Running mean of the observed objective value.
    pub mean_objective: f64,
}

struct BanditState {
    rng: Rng,
    buckets: HashMap<u64, [ArmStats; N_FORMATS]>,
}

/// Coarse feature bucket: matrices with similar scale, row-length
/// profile and padding efficiency share exploration statistics. Buckets
/// quantize the Table-2 features that drive format choice (paper §5.5).
pub fn bucket_of(f: &Features) -> u64 {
    let log2_or_zero = |v: f64| {
        if v >= 1.0 {
            (v.log2().floor() as u64).min(63)
        } else {
            0
        }
    };
    let n = log2_or_zero(f.n);
    let avg = log2_or_zero(f.avg_nnz);
    let std = log2_or_zero(f.std_nnz + 1.0);
    let ell = ((f.ell_ratio.clamp(0.0, 1.0) * 4.0) as u64).min(3);
    (n << 18) | (avg << 12) | (std << 6) | ell
}

/// Epsilon-greedy explorer with count-balanced arm selection.
pub struct Bandit {
    /// f64 bits of the current exploration rate — atomic so operators
    /// can anneal or pause exploration on a live pool.
    explore_rate_bits: AtomicU64,
    /// Auto-anneal target: observations per alternative arm at which a
    /// bucket's exploration reaches zero (None = flat rate forever).
    anneal_target: Option<u64>,
    state: Mutex<BanditState>,
}

impl Bandit {
    /// `explore_rate` is clamped to [0, 1]; `seed` makes the whole
    /// exploration schedule reproducible.
    pub fn new(explore_rate: f64, seed: u64) -> Bandit {
        Bandit::with_anneal(explore_rate, seed, None)
    }

    /// Like [`Bandit::new`] but with per-bucket auto-annealing: a
    /// bucket's effective rate decays linearly from `explore_rate` to 0
    /// as its weakest alternative arm accumulates `target` credited
    /// observations. Counterfactual labels stop being bought once every
    /// alternative has enough evidence — per bucket, so a novel matrix
    /// population resumes exploring at full rate while converged
    /// buckets stay quiet. The rate-0 short-circuit (zero RNG draws,
    /// zero state) is untouched, preserving the frozen-pool
    /// bit-identity property.
    pub fn with_anneal(explore_rate: f64, seed: u64, target: Option<u64>) -> Bandit {
        Bandit {
            explore_rate_bits: AtomicU64::new(explore_rate.clamp(0.0, 1.0).to_bits()),
            anneal_target: target.filter(|t| *t > 0),
            state: Mutex::new(BanditState { rng: Rng::new(seed), buckets: HashMap::new() }),
        }
    }

    pub fn explore_rate(&self) -> f64 {
        f64::from_bits(self.explore_rate_bits.load(Ordering::Acquire))
    }

    /// Change the exploration rate on a live bandit (annealing; 0
    /// pauses exploration entirely).
    pub fn set_explore_rate(&self, rate: f64) {
        self.explore_rate_bits.store(rate.clamp(0.0, 1.0).to_bits(), Ordering::Release);
    }

    /// Route one dispatch: keep the router's `default` format, or —
    /// with probability of the bucket's effective rate (the configured
    /// rate, annealed by arm confidence when a target is set) — the
    /// least-pulled alternative arm in this matrix's feature bucket.
    ///
    /// `explore_rate == 0` short-circuits before touching the lock or
    /// the RNG, so a non-exploring pool is bit-identical to one with no
    /// bandit at all. With exploration on, exactly ONE draw is consumed
    /// per dispatch regardless of annealing, so the schedule stays
    /// deterministic per seed.
    pub fn route(&self, feats: &Features, default: Format) -> RouteChoice {
        let rate = self.explore_rate();
        if rate <= 0.0 {
            return RouteChoice::chosen(default);
        }
        let mut st = self.state.lock().expect("bandit lock");
        let draw = st.rng.f64();
        let arms = st
            .buckets
            .entry(bucket_of(feats))
            .or_insert_with(|| std::array::from_fn(|_| ArmStats::default()));
        let effective = match self.anneal_target {
            None => rate,
            Some(target) => {
                // confidence = the weakest alternative arm's evidence;
                // exploration pays for labels until every alternative
                // has `target` of them, then this bucket goes quiet
                let min_alt = Format::ALL
                    .iter()
                    .filter(|f| **f != default)
                    .map(|f| arms[f.class_id()].observations)
                    .min()
                    .unwrap_or(0);
                rate * (1.0 - min_alt as f64 / target as f64).max(0.0)
            }
        };
        if draw >= effective {
            arms[default.class_id()].pulls += 1;
            return RouteChoice::chosen(default);
        }
        let alt = Format::ALL
            .iter()
            .copied()
            .filter(|f| *f != default)
            .min_by_key(|f| arms[f.class_id()].pulls)
            .expect("more than one format");
        arms[alt.class_id()].pulls += 1;
        RouteChoice { format: alt, explored: true }
    }

    /// Credit an observed objective value to an arm (running mean).
    pub fn observe(&self, feats: &Features, format: Format, objective_value: f64) {
        let mut st = self.state.lock().expect("bandit lock");
        let arms = st
            .buckets
            .entry(bucket_of(feats))
            .or_insert_with(|| std::array::from_fn(|_| ArmStats::default()));
        let arm = &mut arms[format.class_id()];
        arm.observations += 1;
        arm.mean_objective += (objective_value - arm.mean_objective) / arm.observations as f64;
    }

    /// Snapshot of one bucket's arms (stats/debug aid).
    pub fn arms(&self, feats: &Features) -> [ArmStats; N_FORMATS] {
        let st = self.state.lock().expect("bandit lock");
        st.buckets.get(&bucket_of(feats)).copied().unwrap_or_default()
    }

    /// Number of feature buckets with any exploration state.
    pub fn buckets(&self) -> usize {
        self.state.lock().expect("bandit lock").buckets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats(n: f64, avg: f64) -> Features {
        Features {
            n,
            nnz: n * avg,
            avg_nnz: avg,
            var_nnz: 1.0,
            ell_ratio: 0.5,
            median: avg,
            mode: avg,
            std_nnz: 1.0,
        }
    }

    #[test]
    fn zero_rate_never_explores_and_never_draws() {
        let b = Bandit::new(0.0, 7);
        let f = feats(1000.0, 8.0);
        for _ in 0..100 {
            let r = b.route(&f, Format::Csr);
            assert_eq!(r, RouteChoice::chosen(Format::Csr));
        }
        assert_eq!(b.buckets(), 0, "no state may be created at rate 0");
    }

    #[test]
    fn live_annealing_pauses_and_resumes_exploration() {
        let b = Bandit::new(1.0, 5);
        let f = feats(700.0, 5.0);
        assert!(b.route(&f, Format::Csr).explored);
        b.set_explore_rate(0.0);
        assert_eq!(b.explore_rate(), 0.0);
        for _ in 0..50 {
            assert!(!b.route(&f, Format::Csr).explored, "paused bandit must not explore");
        }
        b.set_explore_rate(1.0);
        assert!(b.route(&f, Format::Csr).explored);
    }

    #[test]
    fn explores_at_roughly_the_configured_rate() {
        let b = Bandit::new(0.25, 42);
        let f = feats(5000.0, 12.0);
        let explored = (0..4000).filter(|_| b.route(&f, Format::Csr).explored).count();
        assert!(
            (800..1200).contains(&explored),
            "~25% of 4000 dispatches should explore, got {explored}"
        );
    }

    #[test]
    fn exploration_is_count_balanced_across_alternative_arms() {
        let b = Bandit::new(1.0, 3);
        let f = feats(2000.0, 6.0);
        for _ in 0..99 {
            let r = b.route(&f, Format::Csr);
            assert!(r.explored);
            assert_ne!(r.format, Format::Csr, "exploration must pick a non-default arm");
        }
        let arms = b.arms(&f);
        assert_eq!(arms[Format::Csr.class_id()].pulls, 0);
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            assert_eq!(arms[fmt.class_id()].pulls, 33, "99 pulls split evenly");
        }
    }

    #[test]
    fn annealing_stops_exploration_once_alternatives_have_evidence() {
        let b = Bandit::with_anneal(1.0, 11, Some(4));
        let f = feats(900.0, 6.0);
        assert!(b.route(&f, Format::Csr).explored, "fresh bucket explores at full rate");
        // credit the target evidence to every alternative arm
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            for _ in 0..4 {
                b.observe(&f, fmt, 1.0);
            }
        }
        for _ in 0..200 {
            assert!(
                !b.route(&f, Format::Csr).explored,
                "a fully-confident bucket must stop exploring"
            );
        }
        // a DIFFERENT bucket still explores at full rate
        let fresh = feats(1_000_000.0, 64.0);
        assert_ne!(bucket_of(&f), bucket_of(&fresh));
        assert!(b.route(&fresh, Format::Csr).explored);
    }

    #[test]
    fn annealing_decays_the_rate_with_partial_evidence() {
        let b = Bandit::with_anneal(1.0, 12, Some(8));
        let f = feats(400.0, 3.0);
        // half the target on every alternative -> effective rate 0.5
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            for _ in 0..4 {
                b.observe(&f, fmt, 1.0);
            }
        }
        let explored = (0..2000).filter(|_| b.route(&f, Format::Csr).explored).count();
        assert!(
            (800..1200).contains(&explored),
            "half-confident bucket should explore ~50%, got {explored}/2000"
        );
    }

    #[test]
    fn annealing_keeps_the_rate_zero_short_circuit() {
        let b = Bandit::with_anneal(0.0, 13, Some(4));
        let f = feats(1000.0, 8.0);
        for _ in 0..50 {
            assert_eq!(b.route(&f, Format::Csr), RouteChoice::chosen(Format::Csr));
        }
        assert_eq!(b.buckets(), 0, "rate 0 must stay stateless with annealing configured");
    }

    #[test]
    fn deterministic_per_seed() {
        let f = feats(300.0, 4.0);
        let run = |seed| {
            let b = Bandit::new(0.5, seed);
            (0..64).map(|_| b.route(&f, Format::Ell)).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10), "different seeds give a different schedule");
    }

    #[test]
    fn observe_tracks_running_mean() {
        let b = Bandit::new(0.1, 1);
        let f = feats(100.0, 2.0);
        for v in [2.0, 4.0, 6.0] {
            b.observe(&f, Format::Sell, v);
        }
        let arm = b.arms(&f)[Format::Sell.class_id()];
        assert_eq!(arm.observations, 3);
        assert!((arm.mean_objective - 4.0).abs() < 1e-12);
    }

    #[test]
    fn buckets_separate_scales_but_group_similar_matrices() {
        assert_eq!(bucket_of(&feats(1000.0, 8.0)), bucket_of(&feats(1020.0, 8.5)));
        assert_ne!(bucket_of(&feats(1000.0, 8.0)), bucket_of(&feats(1_000_000.0, 8.0)));
        assert_ne!(bucket_of(&feats(1000.0, 2.0)), bucket_of(&feats(1000.0, 200.0)));
    }
}
