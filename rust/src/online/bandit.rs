//! Per-feature-bucket exploration over joint (format, compile-knob)
//! arms.
//!
//! The offline router only ever sees labels for the corpus it was
//! trained on; under workload drift the buffer of online observations
//! would contain nothing but the predicted decision's outcomes and the
//! trainer could never learn that another format — or another compile
//! knob of the SAME format — now wins. The bandit fixes that: with
//! probability `explore_rate` a dispatch is routed to a *non-predicted*
//! arm so the observation buffer holds counterfactual labels.
//!
//! The arm space is the joint [`Decision`]: one of the four sparse
//! formats crossed with a 12-point representative compile-knob grid
//! ([`knob_arm`]) — the quantization classes of `knob_map` (TB size
//! collapsed to {64, 256}, maxrregcount to {32, 64}, all three memory
//! configs), so every arm maps to a DISTINCT Pallas variant family.
//! Arm choice starts count-balanced within the matrix's feature bucket
//! (the UCB exploration bonus in the limit where unexplored arms
//! dominate) and switches to true per-arm UCB scoring once every
//! alternative FORMAT has `ucb_floor` credited observations, knob arms
//! summed — the same credit annealing uses, so UCB engages strictly
//! before an annealing bucket goes quiet whenever the floor is below
//! the anneal target. Exploration then concentrates on the arms whose
//! observed objective is actually competitive instead of cycling the
//! whole grid forever.
//!
//! Everything is deterministic given the seed and the dispatch order:
//! the RNG is the crate's own xoshiro [`Rng`], consulted exactly once
//! per routed dispatch (zero draws when `explore_rate == 0`, which is
//! what makes the frozen-pool bit-identity property hold).

use crate::coordinator::compile_time::CompileChoice;
use crate::features::Features;
use crate::gen::Rng;
use crate::gpusim::MemConfig;
use crate::sparse::{Format, KernelKind};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of format arms (`Format::ALL`).
pub const N_FORMATS: usize = Format::ALL.len();

/// Representative compile-knob grid the bandit explores: the values
/// `knob_map` distinguishes (TB {64, 256} x regs {32, 64} x the three
/// memory configs). Finer CUDA knob points alias to the same Pallas
/// variant, so exploring them would buy duplicate labels.
pub const KNOB_TBS: [u32; 2] = [64, 256];
pub const KNOB_REGS: [u32; 2] = [32, 64];

/// Knob arms per format (12) and total joint arms (48).
pub const N_KNOBS: usize = KNOB_TBS.len() * KNOB_REGS.len() * MemConfig::ALL.len();
pub const N_ARMS: usize = N_FORMATS * N_KNOBS;

/// Default evidence floor at which exploration switches from
/// count-balancing to per-arm UCB scoring.
pub const DEFAULT_UCB_FLOOR: u64 = 8;

/// The `i`-th knob arm (`0 <= i < N_KNOBS`).
pub fn knob_arm(i: usize) -> CompileChoice {
    let per_tb = KNOB_REGS.len() * MemConfig::ALL.len();
    CompileChoice {
        tb_size: KNOB_TBS[(i / per_tb) % KNOB_TBS.len()],
        maxrregcount: KNOB_REGS[(i % per_tb) / MemConfig::ALL.len()],
        mem: MemConfig::ALL[i % MemConfig::ALL.len()],
    }
}

/// Quantize an arbitrary choice onto the arm grid — the same collapsing
/// `knob_map` applies (TB <= 128 -> small block_rows, regs <= 32 ->
/// narrow chunks), so two choices share an arm iff they select the same
/// Pallas variant family.
pub fn knob_index(c: CompileChoice) -> usize {
    let per_tb = KNOB_REGS.len() * MemConfig::ALL.len();
    let ti = usize::from(c.tb_size > 128);
    let ri = usize::from(c.maxrregcount > 32);
    ti * per_tb + ri * MemConfig::ALL.len() + c.mem.class_id()
}

/// One joint (format, compile-knob) run-time decision — the bandit's
/// arm space, and what the serving shards execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub format: Format,
    pub choice: CompileChoice,
}

impl Decision {
    /// A format decision at the serving-default knobs (the PR 2/3
    /// format-only behavior).
    pub fn format_only(format: Format) -> Decision {
        Decision { format, choice: CompileChoice::serving_default() }
    }

    /// Flat arm index in `[0, N_ARMS)`.
    pub fn arm_index(&self) -> usize {
        self.format.class_id() * N_KNOBS + knob_index(self.choice)
    }

    /// The canonical decision of an arm index.
    pub fn from_arm(i: usize) -> Decision {
        Decision {
            format: Format::from_class_id(i / N_KNOBS).expect("arm index in range"),
            choice: knob_arm(i % N_KNOBS),
        }
    }
}

impl std::fmt::Display for Decision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@{}", self.format, self.choice)
    }
}

/// Routing outcome for one dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteChoice {
    /// Joint decision this dispatch executes.
    pub decision: Decision,
    /// True when the bandit overrode the router's decision.
    pub explored: bool,
}

impl RouteChoice {
    /// The trivial non-exploring choice.
    pub fn chosen(decision: Decision) -> RouteChoice {
        RouteChoice { decision, explored: false }
    }
}

/// Per-arm statistics inside one feature bucket.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArmStats {
    /// Dispatches routed to this arm (chosen + explored).
    pub pulls: u64,
    /// Observations credited to this arm.
    pub observations: u64,
    /// Running mean of the observed objective value.
    pub mean_objective: f64,
}

struct BanditState {
    rng: Rng,
    buckets: HashMap<u64, Box<[ArmStats; N_ARMS]>>,
}

/// Coarse feature bucket: matrices with similar scale, row-length
/// profile and padding efficiency share exploration statistics. Buckets
/// quantize the Table-2 features that drive format choice (paper §5.5).
pub fn bucket_of(f: &Features) -> u64 {
    let log2_or_zero = |v: f64| {
        if v >= 1.0 {
            (v.log2().floor() as u64).min(63)
        } else {
            0
        }
    };
    let n = log2_or_zero(f.n);
    let avg = log2_or_zero(f.avg_nnz);
    let std = log2_or_zero(f.std_nnz + 1.0);
    let ell = ((f.ell_ratio.clamp(0.0, 1.0) * 4.0) as u64).min(3);
    (n << 18) | (avg << 12) | (std << 6) | ell
}

/// Kind-qualified feature bucket: the kernel kind is part of the
/// request class, so SpMV and solve (SpTRSV / SymGS) evidence for the
/// same matrix lands in DISTINCT buckets and a solve's cost profile can
/// never skew the product arms (or vice versa). The kind id sits above
/// [`bucket_of`]'s feature bits (n occupies bits 18..24).
pub fn bucket_of_kind(f: &Features, kind: KernelKind) -> u64 {
    bucket_of(f) | ((kind.class_id() as u64) << 24)
}

/// Epsilon-greedy explorer over joint arms, count-balanced until the
/// evidence floor, per-arm UCB after.
pub struct Bandit {
    /// f64 bits of the current exploration rate — atomic so operators
    /// can anneal or pause exploration on a live pool.
    explore_rate_bits: AtomicU64,
    /// Auto-anneal target: observations per alternative format at which
    /// a bucket's exploration reaches zero (None = flat rate forever).
    anneal_target: Option<u64>,
    /// Evidence floor switching arm selection to UCB (0 = never).
    ucb_floor: u64,
    /// Whether lower objective values are better (the objective's
    /// `minimize()`); flips the UCB value term.
    minimize: bool,
    /// Explore knob arms too (false = format arms only, the PR 2/3
    /// behavior).
    joint: bool,
    /// Exploration picks made through the UCB scorer (telemetry).
    ucb_routes: AtomicU64,
    state: Mutex<BanditState>,
}

impl Bandit {
    /// `explore_rate` is clamped to [0, 1]; `seed` makes the whole
    /// exploration schedule reproducible.
    pub fn new(explore_rate: f64, seed: u64) -> Bandit {
        Bandit::with_anneal(explore_rate, seed, None)
    }

    /// Like [`Bandit::new`] but with per-bucket auto-annealing: a
    /// bucket's effective rate decays linearly from `explore_rate` to 0
    /// as its weakest alternative format accumulates `target` credited
    /// observations (summed across that format's knob arms).
    pub fn with_anneal(explore_rate: f64, seed: u64, target: Option<u64>) -> Bandit {
        Bandit::with_params(explore_rate, seed, target, DEFAULT_UCB_FLOOR, true, true)
    }

    /// Full-control constructor: annealing, the UCB evidence floor,
    /// the objective direction, and whether knob arms are explored at
    /// all (`joint = false` restricts exploration to the four format
    /// arms at the default knob — the PR 2/3 arm space).
    pub fn with_params(
        explore_rate: f64,
        seed: u64,
        anneal_target: Option<u64>,
        ucb_floor: u64,
        minimize: bool,
        joint: bool,
    ) -> Bandit {
        Bandit {
            explore_rate_bits: AtomicU64::new(explore_rate.clamp(0.0, 1.0).to_bits()),
            anneal_target: anneal_target.filter(|t| *t > 0),
            ucb_floor,
            minimize,
            joint,
            ucb_routes: AtomicU64::new(0),
            state: Mutex::new(BanditState { rng: Rng::new(seed), buckets: HashMap::new() }),
        }
    }

    pub fn explore_rate(&self) -> f64 {
        f64::from_bits(self.explore_rate_bits.load(Ordering::Acquire))
    }

    /// Change the exploration rate on a live bandit (annealing; 0
    /// pauses exploration entirely).
    pub fn set_explore_rate(&self, rate: f64) {
        self.explore_rate_bits.store(rate.clamp(0.0, 1.0).to_bits(), Ordering::Release);
    }

    /// Exploration picks that went through the per-arm UCB scorer
    /// (0 until every alternative arm crosses the evidence floor).
    pub fn ucb_routes(&self) -> u64 {
        self.ucb_routes.load(Ordering::Relaxed)
    }

    /// Alternative arm indices for a default arm: every other joint arm
    /// when knob exploration is on, the other formats at the default's
    /// knob otherwise.
    fn alternatives(&self, default_arm: usize) -> Vec<usize> {
        if self.joint {
            (0..N_ARMS).filter(|a| *a != default_arm).collect()
        } else {
            let k = default_arm % N_KNOBS;
            (0..N_FORMATS).map(|f| f * N_KNOBS + k).filter(|a| *a != default_arm).collect()
        }
    }

    /// Route one dispatch: keep the router's `default` decision, or —
    /// with probability of the bucket's effective rate (the configured
    /// rate, annealed by format-arm confidence when a target is set) —
    /// an alternative arm in this matrix's feature bucket.
    ///
    /// `explore_rate == 0` short-circuits before touching the lock or
    /// the RNG, so a non-exploring pool is bit-identical to one with no
    /// bandit at all. With exploration on, exactly ONE draw is consumed
    /// per dispatch regardless of annealing or the UCB floor, so the
    /// schedule stays deterministic per seed.
    pub fn route(&self, feats: &Features, default: Decision) -> RouteChoice {
        self.route_kind(KernelKind::Spmv, feats, default)
    }

    /// [`route`](Self::route) with an explicit kernel kind: solve
    /// dispatches explore in their own kind-qualified buckets (see
    /// [`bucket_of_kind`]) so SpMV and SpTRSV/SymGS evidence never mix.
    pub fn route_kind(&self, kind: KernelKind, feats: &Features, default: Decision) -> RouteChoice {
        let rate = self.explore_rate();
        if rate <= 0.0 {
            return RouteChoice::chosen(default);
        }
        let mut st = self.state.lock().expect("bandit lock");
        let draw = st.rng.f64();
        let arms = st
            .buckets
            .entry(bucket_of_kind(feats, kind))
            .or_insert_with(|| Box::new([ArmStats::default(); N_ARMS]));
        let default_arm = default.arm_index();
        // The weakest alternative FORMAT's evidence (knob arms summed);
        // both confidence gates read it. Annealing: exploration pays
        // for labels until every alternative format has `target` of
        // them, then the bucket goes quiet. UCB floor: credited the
        // same way — NOT per individual arm, where the 47-alternative
        // joint space would need ~6x the anneal target of explored
        // labels and an annealing bucket would go quiet before UCB
        // ever engaged. With ucb_floor below anneal_target UCB gets a
        // live window; under-sampled knob arms are then prioritized by
        // the UCB bonus itself.
        let min_alt_evidence = {
            let view: &[ArmStats; N_ARMS] = arms;
            Format::ALL
                .iter()
                .filter(|f| **f != default.format)
                .map(|f| format_observations(view, **f))
                .min()
                .unwrap_or(0)
        };
        let effective = match self.anneal_target {
            None => rate,
            Some(target) => rate * (1.0 - min_alt_evidence as f64 / target as f64).max(0.0),
        };
        if draw >= effective {
            arms[default_arm].pulls += 1;
            return RouteChoice::chosen(default);
        }
        let alts = self.alternatives(default_arm);
        let alt = if self.ucb_floor > 0 && min_alt_evidence >= self.ucb_floor {
            self.ucb_routes.fetch_add(1, Ordering::Relaxed);
            let view: &[ArmStats; N_ARMS] = arms;
            ucb_pick(view, &alts, self.minimize)
        } else {
            // count-balancing: the least-pulled alternative goes first,
            // so every arm gets sampled instead of one lucky arm
            alts.iter().copied().min_by_key(|a| arms[*a].pulls).expect("more than one arm")
        };
        arms[alt].pulls += 1;
        RouteChoice { decision: Decision::from_arm(alt), explored: true }
    }

    /// Credit an observed objective value to an arm (running mean).
    pub fn observe(&self, feats: &Features, decision: Decision, objective_value: f64) {
        self.observe_kind(KernelKind::Spmv, feats, decision, objective_value);
    }

    /// [`observe`](Self::observe) with an explicit kernel kind — must
    /// match the kind the dispatch was routed with.
    pub fn observe_kind(
        &self,
        kind: KernelKind,
        feats: &Features,
        decision: Decision,
        objective_value: f64,
    ) {
        let mut st = self.state.lock().expect("bandit lock");
        let arms = st
            .buckets
            .entry(bucket_of_kind(feats, kind))
            .or_insert_with(|| Box::new([ArmStats::default(); N_ARMS]));
        let arm = &mut arms[decision.arm_index()];
        arm.observations += 1;
        arm.mean_objective += (objective_value - arm.mean_objective) / arm.observations as f64;
    }

    /// Snapshot of one bucket's arms, `Decision::from_arm` order
    /// (stats/debug aid).
    pub fn arms(&self, feats: &Features) -> Vec<ArmStats> {
        self.arms_kind(KernelKind::Spmv, feats)
    }

    /// [`arms`](Self::arms) for an explicit kernel kind's bucket.
    pub fn arms_kind(&self, kind: KernelKind, feats: &Features) -> Vec<ArmStats> {
        let st = self.state.lock().expect("bandit lock");
        match st.buckets.get(&bucket_of_kind(feats, kind)) {
            Some(a) => a.to_vec(),
            None => vec![ArmStats::default(); N_ARMS],
        }
    }

    /// Number of feature buckets with any exploration state.
    pub fn buckets(&self) -> usize {
        self.state.lock().expect("bandit lock").buckets.len()
    }
}

/// Total credited observations of a format across its knob arms.
fn format_observations(arms: &[ArmStats; N_ARMS], format: Format) -> u64 {
    let base = format.class_id() * N_KNOBS;
    arms[base..base + N_KNOBS].iter().map(|a| a.observations).sum()
}

/// Scale-invariant UCB over the alternative arms: the value term is the
/// arm's mean objective normalized against the best alternative mean
/// (in (0, 1], direction-corrected for minimize/maximize objectives),
/// plus the standard `sqrt(2 ln T / n)` bonus. Never-observed arms get
/// the optimistic maximum value (`ratio` returns 1.0 on a zero mean)
/// and are excluded from the baseline — a 0.0 placeholder mean would
/// otherwise BE the best minimize mean, flatten every value term to
/// 1.0, and degrade UCB to the count-balancing it replaces until all
/// 47 joint alternatives had evidence. Deterministic: ties go to the
/// lowest arm index.
fn ucb_pick(arms: &[ArmStats; N_ARMS], alts: &[usize], minimize: bool) -> usize {
    let total: u64 = alts.iter().map(|a| arms[*a].observations).sum();
    let total = total.max(1) as f64;
    let best_mean = alts
        .iter()
        .filter(|a| arms[**a].observations > 0)
        .map(|a| arms[*a].mean_objective)
        .fold(None::<f64>, |acc, v| {
            Some(match acc {
                None => v,
                Some(b) => {
                    if (minimize && v < b) || (!minimize && v > b) {
                        v
                    } else {
                        b
                    }
                }
            })
        })
        .unwrap_or(0.0);
    let ratio = |num: f64, den: f64| {
        if num > 0.0 && den > 0.0 {
            (num / den).min(1.0)
        } else {
            1.0
        }
    };
    let mut best: Option<(f64, usize)> = None;
    for &a in alts {
        let n = arms[a].observations.max(1) as f64;
        let value = if minimize {
            ratio(best_mean, arms[a].mean_objective)
        } else {
            ratio(arms[a].mean_objective, best_mean)
        };
        let score = value + (2.0 * total.ln() / n).sqrt();
        if best.is_none_or(|(bs, _)| score > bs) {
            best = Some((score, a));
        }
    }
    best.expect("non-empty alternatives").1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats(n: f64, avg: f64) -> Features {
        Features {
            n,
            nnz: n * avg,
            avg_nnz: avg,
            var_nnz: 1.0,
            ell_ratio: 0.5,
            median: avg,
            mode: avg,
            std_nnz: 1.0,
        }
    }

    fn fmt_default(format: Format) -> Decision {
        Decision::format_only(format)
    }

    /// Format-only bandit (the PR 2/3 arm space) with no UCB.
    fn format_bandit(rate: f64, seed: u64, target: Option<u64>) -> Bandit {
        Bandit::with_params(rate, seed, target, 0, true, false)
    }

    #[test]
    fn arm_indexing_roundtrips_the_whole_grid() {
        for i in 0..N_ARMS {
            let d = Decision::from_arm(i);
            assert_eq!(d.arm_index(), i, "arm {i} must roundtrip ({d})");
        }
        // the serving default quantizes onto its own canonical arm
        let d = fmt_default(Format::Ell);
        assert_eq!(Decision::from_arm(d.arm_index()), d);
        // finer CUDA knob points alias exactly as knob_map collapses
        let fine = Decision {
            format: Format::Ell,
            choice: CompileChoice {
                tb_size: 512,
                maxrregcount: 128,
                mem: MemConfig::Default,
            },
        };
        assert_eq!(
            fine.arm_index(),
            Decision {
                format: Format::Ell,
                choice: CompileChoice {
                    tb_size: 256,
                    maxrregcount: 64,
                    mem: MemConfig::Default
                },
            }
            .arm_index()
        );
    }

    #[test]
    fn zero_rate_never_explores_and_never_draws() {
        let b = Bandit::new(0.0, 7);
        let f = feats(1000.0, 8.0);
        for _ in 0..100 {
            let r = b.route(&f, fmt_default(Format::Csr));
            assert_eq!(r, RouteChoice::chosen(fmt_default(Format::Csr)));
        }
        assert_eq!(b.buckets(), 0, "no state may be created at rate 0");
    }

    #[test]
    fn live_annealing_pauses_and_resumes_exploration() {
        let b = Bandit::new(1.0, 5);
        let f = feats(700.0, 5.0);
        assert!(b.route(&f, fmt_default(Format::Csr)).explored);
        b.set_explore_rate(0.0);
        assert_eq!(b.explore_rate(), 0.0);
        for _ in 0..50 {
            assert!(
                !b.route(&f, fmt_default(Format::Csr)).explored,
                "paused bandit must not explore"
            );
        }
        b.set_explore_rate(1.0);
        assert!(b.route(&f, fmt_default(Format::Csr)).explored);
    }

    #[test]
    fn explores_at_roughly_the_configured_rate() {
        let b = Bandit::new(0.25, 42);
        let f = feats(5000.0, 12.0);
        let explored =
            (0..4000).filter(|_| b.route(&f, fmt_default(Format::Csr)).explored).count();
        assert!(
            (800..1200).contains(&explored),
            "~25% of 4000 dispatches should explore, got {explored}"
        );
    }

    #[test]
    fn kinds_get_disjoint_buckets_and_evidence() {
        let b = Bandit::new(1.0, 11);
        let f = feats(900.0, 7.0);
        let d = fmt_default(Format::Csr);
        assert_eq!(bucket_of_kind(&f, KernelKind::Spmv), bucket_of(&f), "spmv is the plain bucket");
        let keys: std::collections::HashSet<u64> =
            KernelKind::ALL.iter().map(|k| bucket_of_kind(&f, *k)).collect();
        assert_eq!(keys.len(), KernelKind::N, "each kind must hash to its own bucket");
        // evidence credited under one kind is invisible to the others
        b.observe_kind(KernelKind::Sptrsv, &f, d, 4.0);
        assert_eq!(b.arms_kind(KernelKind::Sptrsv, &f)[d.arm_index()].observations, 1);
        assert_eq!(b.arms(&f)[d.arm_index()].observations, 0);
        assert_eq!(b.arms_kind(KernelKind::Symgs, &f)[d.arm_index()].observations, 0);
        // routing a solve creates a second bucket, not more state in the spmv one
        let _ = b.route_kind(KernelKind::Symgs, &f, d);
        assert_eq!(b.buckets(), 2);
    }

    #[test]
    fn joint_exploration_is_count_balanced_across_all_arms() {
        let b = Bandit::with_params(1.0, 3, None, 0, true, true);
        let f = feats(2000.0, 6.0);
        let default = fmt_default(Format::Csr);
        for _ in 0..(2 * (N_ARMS - 1)) {
            let r = b.route(&f, default);
            assert!(r.explored);
            assert_ne!(r.decision, default, "exploration must pick a non-default arm");
        }
        let arms = b.arms(&f);
        assert_eq!(arms[default.arm_index()].pulls, 0);
        for (i, a) in arms.iter().enumerate() {
            if i != default.arm_index() {
                assert_eq!(a.pulls, 2, "arm {i}: {} pulls split evenly", 2 * (N_ARMS - 1));
            }
        }
    }

    #[test]
    fn format_only_mode_restricts_exploration_to_format_arms() {
        let b = format_bandit(1.0, 3, None);
        let f = feats(2000.0, 6.0);
        let default = fmt_default(Format::Csr);
        for _ in 0..99 {
            let r = b.route(&f, default);
            assert!(r.explored);
            assert_ne!(r.decision.format, Format::Csr);
            assert_eq!(
                r.decision.choice,
                CompileChoice::serving_default(),
                "format-only exploration must keep the default knob"
            );
        }
        let arms = b.arms(&f);
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            assert_eq!(arms[fmt_default(fmt).arm_index()].pulls, 33, "99 pulls split evenly");
        }
    }

    #[test]
    fn ucb_takes_over_once_every_alternative_has_evidence() {
        // format-only space (3 alternatives) with a floor of 2
        let b = Bandit::with_params(1.0, 17, None, 2, true, false);
        let f = feats(900.0, 6.0);
        let default = fmt_default(Format::Csr);
        // credit evidence: ELL clearly best, BELL/SELL poor
        for (fmt, cost) in [(Format::Ell, 1.0), (Format::Bell, 9.0), (Format::Sell, 9.0)] {
            for _ in 0..2 {
                b.observe(&f, fmt_default(fmt), cost);
            }
        }
        assert_eq!(b.ucb_routes(), 0);
        let picks: Vec<Format> = (0..60).map(|_| b.route(&f, default).decision.format).collect();
        assert!(b.ucb_routes() > 0, "the floor is met, UCB must engage");
        let ell = picks.iter().filter(|f| **f == Format::Ell).count();
        assert!(
            ell > picks.len() / 2,
            "UCB must concentrate on the best-observed arm (ELL got {ell}/{})",
            picks.len()
        );
    }

    #[test]
    fn joint_ucb_engages_before_an_annealing_bucket_goes_quiet() {
        // floor 2 < anneal target 8: once each alternative format has 2
        // credited observations (summed across knob arms), exploration
        // is still live (effective rate 0.75) and must route via UCB —
        // a per-arm floor would need 47x2 labels here and never engage
        let b = Bandit::with_params(1.0, 23, Some(8), 2, true, true);
        let f = feats(600.0, 7.0);
        let default = fmt_default(Format::Csr);
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            for k in 0..2 {
                b.observe(&f, Decision { format: fmt, choice: knob_arm(k) }, 1.0 + k as f64);
            }
        }
        let explored = (0..400).filter(|_| b.route(&f, default).explored).count();
        assert!(explored > 0, "the bucket must still be exploring");
        assert!(b.ucb_routes() > 0, "UCB must engage while exploration is live");
    }

    #[test]
    fn joint_ucb_concentrates_despite_unobserved_arms() {
        // minimize; only ONE knob arm per alternative format has
        // evidence when the floor is crossed. The baseline must come
        // from OBSERVED arms only: with never-observed 0.0 means
        // included, best_mean would be 0.0, every value term would
        // flatten to 1.0, and UCB would cycle the grid exactly like
        // the count-balancer it replaces.
        let best = Decision { format: Format::Ell, choice: knob_arm(0) };
        let cost = |d: Decision| if d == best { 1.0 } else { 40.0 };
        let b = Bandit::with_params(1.0, 29, None, 2, true, true);
        let f = feats(800.0, 9.0);
        let default = fmt_default(Format::Csr);
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            let d = Decision { format: fmt, choice: knob_arm(0) };
            for _ in 0..2 {
                b.observe(&f, d, cost(d));
            }
        }
        // realistic loop: every routed dispatch is observed back
        let mut picks = [0usize; N_ARMS];
        for _ in 0..300 {
            let r = b.route(&f, default);
            b.observe(&f, r.decision, cost(r.decision));
            picks[r.decision.arm_index()] += 1;
        }
        assert!(b.ucb_routes() > 0, "floor 2 is crossed from the start");
        let best_picks = picks[best.arm_index()];
        let runner_up =
            picks.iter().enumerate().filter(|(i, _)| *i != best.arm_index()).map(|(_, c)| *c);
        assert!(
            best_picks > runner_up.max().unwrap(),
            "the best-observed arm must be the modal pick, got {picks:?}"
        );
        assert!(
            best_picks > 2 * 300 / N_ARMS,
            "concentration must beat the uniform share ({best_picks}/300)"
        );
    }

    #[test]
    fn ucb_respects_maximize_objectives() {
        let b = Bandit::with_params(1.0, 18, None, 1, false, false);
        let f = feats(900.0, 6.0);
        // higher is better now: SELL wins
        for (fmt, v) in [(Format::Ell, 1.0), (Format::Bell, 2.0), (Format::Sell, 50.0)] {
            b.observe(&f, fmt_default(fmt), v);
        }
        let picks: Vec<Format> =
            (0..60).map(|_| b.route(&f, fmt_default(Format::Csr)).decision.format).collect();
        let sell = picks.iter().filter(|f| **f == Format::Sell).count();
        assert!(sell > picks.len() / 2, "maximize objective must favor SELL ({sell})");
    }

    #[test]
    fn annealing_stops_exploration_once_alternatives_have_evidence() {
        let b = format_bandit(1.0, 11, Some(4));
        let f = feats(900.0, 6.0);
        assert!(
            b.route(&f, fmt_default(Format::Csr)).explored,
            "fresh bucket explores at full rate"
        );
        // credit the target evidence to every alternative format
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            for _ in 0..4 {
                b.observe(&f, fmt_default(fmt), 1.0);
            }
        }
        for _ in 0..200 {
            assert!(
                !b.route(&f, fmt_default(Format::Csr)).explored,
                "a fully-confident bucket must stop exploring"
            );
        }
        // a DIFFERENT bucket still explores at full rate
        let fresh = feats(1_000_000.0, 64.0);
        assert_ne!(bucket_of(&f), bucket_of(&fresh));
        assert!(b.route(&fresh, fmt_default(Format::Csr)).explored);
    }

    #[test]
    fn annealing_counts_evidence_across_a_formats_knob_arms() {
        // joint bandit: evidence spread over DIFFERENT knob arms of the
        // alternative formats still anneals the bucket
        let b = Bandit::with_params(1.0, 19, Some(4), 0, true, true);
        let f = feats(450.0, 5.0);
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            for k in 0..4 {
                b.observe(&f, Decision { format: fmt, choice: knob_arm(k) }, 1.0);
            }
        }
        for _ in 0..100 {
            assert!(!b.route(&f, fmt_default(Format::Csr)).explored);
        }
    }

    #[test]
    fn annealing_decays_the_rate_with_partial_evidence() {
        let b = format_bandit(1.0, 12, Some(8));
        let f = feats(400.0, 3.0);
        // half the target on every alternative -> effective rate 0.5
        for fmt in [Format::Ell, Format::Bell, Format::Sell] {
            for _ in 0..4 {
                b.observe(&f, fmt_default(fmt), 1.0);
            }
        }
        let explored =
            (0..2000).filter(|_| b.route(&f, fmt_default(Format::Csr)).explored).count();
        assert!(
            (800..1200).contains(&explored),
            "half-confident bucket should explore ~50%, got {explored}/2000"
        );
    }

    #[test]
    fn annealing_keeps_the_rate_zero_short_circuit() {
        let b = Bandit::with_anneal(0.0, 13, Some(4));
        let f = feats(1000.0, 8.0);
        for _ in 0..50 {
            assert_eq!(
                b.route(&f, fmt_default(Format::Csr)),
                RouteChoice::chosen(fmt_default(Format::Csr))
            );
        }
        assert_eq!(b.buckets(), 0, "rate 0 must stay stateless with annealing configured");
    }

    #[test]
    fn deterministic_per_seed() {
        let f = feats(300.0, 4.0);
        let run = |seed| {
            let b = Bandit::new(0.5, seed);
            (0..64).map(|_| b.route(&f, fmt_default(Format::Ell))).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10), "different seeds give a different schedule");
    }

    #[test]
    fn observe_tracks_running_mean() {
        let b = Bandit::new(0.1, 1);
        let f = feats(100.0, 2.0);
        for v in [2.0, 4.0, 6.0] {
            b.observe(&f, fmt_default(Format::Sell), v);
        }
        let arm = b.arms(&f)[fmt_default(Format::Sell).arm_index()];
        assert_eq!(arm.observations, 3);
        assert!((arm.mean_objective - 4.0).abs() < 1e-12);
    }

    #[test]
    fn buckets_separate_scales_but_group_similar_matrices() {
        assert_eq!(bucket_of(&feats(1000.0, 8.0)), bucket_of(&feats(1020.0, 8.5)));
        assert_ne!(bucket_of(&feats(1000.0, 8.0)), bucket_of(&feats(1_000_000.0, 8.0)));
        assert_ne!(bucket_of(&feats(1000.0, 2.0)), bucket_of(&feats(1000.0, 200.0)));
    }
}
