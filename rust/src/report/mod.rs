//! Reporting kit: aligned-text tables (the benches print the paper's
//! tables/figures as rows), TSV dumps under `reports/`, and a tiny
//! timing harness used by the `harness = false` bench binaries
//! (criterion is unavailable in the offline environment — Cargo.toml).

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

/// Column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (w, c) in widths.iter_mut().zip(r) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1));
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    /// Print to stdout and save as TSV under `reports/<name>.tsv`.
    pub fn emit(&self, name: &str) {
        println!("{}", self.render());
        let dir = Path::new("reports");
        if std::fs::create_dir_all(dir).is_ok() {
            let mut tsv = self.header.join("\t");
            for r in &self.rows {
                tsv.push('\n');
                tsv.push_str(&r.join("\t"));
            }
            tsv.push('\n');
            let _ = std::fs::write(dir.join(format!("{name}.tsv")), tsv);
        }
    }

    /// Machine-readable form: `{"title", "header", "rows"}` (no serde
    /// in the offline environment, so this is a hand-rolled emitter
    /// with full string escaping).
    pub fn to_json(&self) -> String {
        let arr = |cells: &[String]| {
            let quoted: Vec<String> = cells.iter().map(|c| json_escape(c)).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| arr(r)).collect();
        format!(
            "{{\"title\":{},\"header\":{},\"rows\":[{}]}}",
            json_escape(&self.title),
            arr(&self.header),
            rows.join(",")
        )
    }

    /// Save as `reports/BENCH_<name>.json` — the per-PR perf-trajectory
    /// artifact the CI bench-smoke job uploads.
    pub fn emit_json(&self, name: &str) {
        let dir = Path::new("reports");
        if std::fs::create_dir_all(dir).is_ok() {
            let mut body = self.to_json();
            body.push('\n');
            let _ = std::fs::write(dir.join(format!("BENCH_{name}.json")), body);
        }
    }
}

/// Minimal JSON string encoder: returns `s` quoted, with quotes,
/// backslashes, and control characters escaped per RFC 8259 (hostile
/// matrix names / knob extras must not corrupt `BENCH_*.json` for
/// `tools/bench_gate.py`). Shared with the `obs` event journal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float with engineering-friendly precision.
pub fn fmt_g(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || v.abs() < 0.01 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

/// Percent improvement of `new` over `base` for a minimized metric.
pub fn pct_improvement(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (base - new) / base * 100.0
    }
}

/// Percent gain of `new` over `base` for a maximized metric.
pub fn pct_gain(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (new - base) / base * 100.0
    }
}

/// Timing result of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u64,
    pub total_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Measure `f` `iters` times (after `warmup` runs).
pub fn bench<F: FnMut()>(warmup: u64, iters: u64, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut min_s = f64::INFINITY;
    let mut max_s = 0.0f64;
    let t0 = Instant::now();
    let mut last = t0;
    for _ in 0..iters {
        f();
        let now = Instant::now();
        let d = (now - last).as_secs_f64();
        min_s = min_s.min(d);
        max_s = max_s.max(d);
        last = now;
    }
    let total_s = t0.elapsed().as_secs_f64();
    Timing { iters, total_s, mean_s: total_s / iters as f64, min_s, max_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["bbbb".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("bbbb"));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_form_escapes_and_structures() {
        let mut t = Table::new("T \"quoted\"", &["a", "b"]);
        t.row(vec!["x\ty".into(), "1".into()]);
        let j = t.to_json();
        assert_eq!(
            j,
            "{\"title\":\"T \\\"quoted\\\"\",\"header\":[\"a\",\"b\"],\
             \"rows\":[[\"x\\ty\",\"1\"]]}"
        );
    }

    /// Strict recursive-descent parser for the JSON subset `to_json`
    /// emits (objects / arrays / strings with full escape handling).
    /// Independent of the emitter so the round-trip test actually
    /// exercises RFC 8259 escaping rather than mirroring it.
    mod strict_json {
        #[derive(Debug, Clone, PartialEq)]
        pub enum Value {
            Str(String),
            Arr(Vec<Value>),
            Obj(Vec<(String, Value)>),
        }

        pub fn parse(s: &str) -> Result<Value, String> {
            let chars: Vec<char> = s.chars().collect();
            let mut pos = 0usize;
            let v = value(&chars, &mut pos)?;
            skip_ws(&chars, &mut pos);
            if pos != chars.len() {
                return Err(format!("trailing garbage at {pos}"));
            }
            Ok(v)
        }

        fn skip_ws(c: &[char], pos: &mut usize) {
            while *pos < c.len() && c[*pos].is_ascii_whitespace() {
                *pos += 1;
            }
        }

        fn expect(c: &[char], pos: &mut usize, want: char) -> Result<(), String> {
            if c.get(*pos) == Some(&want) {
                *pos += 1;
                Ok(())
            } else {
                Err(format!("expected {want:?} at {pos}, got {:?}", c.get(*pos)))
            }
        }

        fn value(c: &[char], pos: &mut usize) -> Result<Value, String> {
            skip_ws(c, pos);
            match c.get(*pos) {
                Some('"') => string(c, pos).map(Value::Str),
                Some('[') => {
                    *pos += 1;
                    let mut items = Vec::new();
                    skip_ws(c, pos);
                    if c.get(*pos) == Some(&']') {
                        *pos += 1;
                        return Ok(Value::Arr(items));
                    }
                    loop {
                        items.push(value(c, pos)?);
                        skip_ws(c, pos);
                        match c.get(*pos) {
                            Some(',') => *pos += 1,
                            Some(']') => {
                                *pos += 1;
                                return Ok(Value::Arr(items));
                            }
                            other => return Err(format!("bad array sep {other:?}")),
                        }
                    }
                }
                Some('{') => {
                    *pos += 1;
                    let mut entries = Vec::new();
                    skip_ws(c, pos);
                    if c.get(*pos) == Some(&'}') {
                        *pos += 1;
                        return Ok(Value::Obj(entries));
                    }
                    loop {
                        skip_ws(c, pos);
                        let k = string(c, pos)?;
                        skip_ws(c, pos);
                        expect(c, pos, ':')?;
                        entries.push((k, value(c, pos)?));
                        skip_ws(c, pos);
                        match c.get(*pos) {
                            Some(',') => *pos += 1,
                            Some('}') => {
                                *pos += 1;
                                return Ok(Value::Obj(entries));
                            }
                            other => return Err(format!("bad object sep {other:?}")),
                        }
                    }
                }
                other => Err(format!("unexpected {other:?} at {pos}")),
            }
        }

        fn string(c: &[char], pos: &mut usize) -> Result<String, String> {
            expect(c, pos, '"')?;
            let mut out = String::new();
            loop {
                match c.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some('"') => {
                        *pos += 1;
                        return Ok(out);
                    }
                    Some(ch) if (*ch as u32) < 0x20 => {
                        return Err(format!("raw control char {:#x} in string", *ch as u32));
                    }
                    Some('\\') => {
                        *pos += 1;
                        let esc = c.get(*pos).ok_or("dangling escape")?;
                        *pos += 1;
                        match esc {
                            '"' => out.push('"'),
                            '\\' => out.push('\\'),
                            '/' => out.push('/'),
                            'n' => out.push('\n'),
                            'r' => out.push('\r'),
                            't' => out.push('\t'),
                            'b' => out.push('\u{8}'),
                            'f' => out.push('\u{c}'),
                            'u' => {
                                let hex: String =
                                    c.get(*pos..*pos + 4).ok_or("short \\u")?.iter().collect();
                                *pos += 4;
                                let code = u32::from_str_radix(&hex, 16)
                                    .map_err(|e| format!("bad \\u{hex}: {e}"))?;
                                let ch = char::from_u32(code)
                                    .ok_or_else(|| format!("bad codepoint {code}"))?;
                                out.push(ch);
                            }
                            other => return Err(format!("bad escape \\{other}")),
                        }
                    }
                    Some(ch) => {
                        out.push(*ch);
                        *pos += 1;
                    }
                }
            }
        }
    }

    #[test]
    fn to_json_round_trips_hostile_strings_through_a_real_parser() {
        use strict_json::Value;
        // quotes, backslashes, every named control escape, raw control
        // chars, unicode, and a Windows path — everything that could
        // leak from a matrix name or knob extra into BENCH_*.json
        let hostile = [
            "plain",
            "quo\"te",
            "back\\slash",
            "line\nbreak\r\ttab",
            "bell\u{7}null\u{0}esc\u{1b}",
            "C:\\mats\\\"weird\".mtx",
            "日本語 + ε",
            "",
        ];
        let mut t = Table::new(hostile[5], &["name", "v"]);
        for (i, h) in hostile.iter().enumerate() {
            t.row(vec![h.to_string(), i.to_string()]);
        }
        let parsed = strict_json::parse(&t.to_json()).expect("emitter must produce valid JSON");
        let Value::Obj(entries) = parsed else { panic!("top level must be an object") };
        let get = |k: &str| entries.iter().find(|(n, _)| n == k).map(|(_, v)| v.clone());
        assert_eq!(get("title"), Some(Value::Str(hostile[5].to_string())));
        let Some(Value::Arr(rows)) = get("rows") else { panic!("rows must be an array") };
        assert_eq!(rows.len(), hostile.len());
        for (row, h) in rows.iter().zip(hostile) {
            let Value::Arr(cells) = row else { panic!("row must be an array") };
            assert_eq!(cells[0], Value::Str(h.to_string()), "round-trip of {h:?}");
        }
        // the parser itself must reject what the escaper prevents
        assert!(strict_json::parse("{\"a\":\"raw\ncontrol\"}").is_err());
        assert!(strict_json::parse("[\"dangling\\").is_err());
    }

    #[test]
    fn improvement_math() {
        assert_eq!(pct_improvement(2.0, 1.0), 50.0);
        assert_eq!(pct_gain(2.0, 3.0), 50.0);
        assert_eq!(pct_improvement(0.0, 1.0), 0.0);
    }

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let t = bench(1, 10, || n += 1);
        assert_eq!(n, 11);
        assert_eq!(t.iters, 10);
        assert!(t.mean_s >= 0.0 && t.min_s <= t.max_s);
    }

    #[test]
    fn fmt_g_ranges() {
        assert_eq!(fmt_g(0.0), "0");
        assert!(fmt_g(12345.0).contains('e'));
        assert!(fmt_g(0.5).starts_with("0.5"));
    }
}
