//! COO (coordinate) format — SuiteSparse's on-disk default (paper §7.5)
//! and the input format of the run-time optimization mode.

use super::{Storage, SpMv};

/// Coordinate-format sparse matrix (structure-of-arrays).
///
/// Entries need not be sorted; duplicates are allowed and accumulate
/// (matching SuiteSparse Matrix Market semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    pub n_rows: usize,
    pub n_cols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        Coo { n_rows, n_cols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(n_rows: usize, n_cols: usize, cap: usize) -> Self {
        Coo {
            n_rows,
            n_cols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    /// Push one entry. Debug-asserts bounds; zero values are kept (they
    /// are structurally significant for some generators).
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        debug_assert!(row < self.n_rows && col < self.n_cols, "entry out of bounds");
        self.rows.push(row as u32);
        self.cols.push(col as u32);
        self.vals.push(val);
    }

    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Sort entries by (row, col). Required before CSR conversion when the
    /// source was unsorted; stable so duplicate ordering is deterministic.
    pub fn sort(&mut self) {
        let mut idx: Vec<u32> = (0..self.len() as u32).collect();
        idx.sort_by_key(|&i| (self.rows[i as usize], self.cols[i as usize]));
        self.rows = idx.iter().map(|&i| self.rows[i as usize]).collect();
        self.cols = idx.iter().map(|&i| self.cols[i as usize]).collect();
        self.vals = idx.iter().map(|&i| self.vals[i as usize]).collect();
    }

    /// Per-row non-zero counts — the basis of every sparsity feature.
    pub fn row_counts(&self) -> Vec<u32> {
        let mut c = vec![0u32; self.n_rows];
        for &r in &self.rows {
            c[r as usize] += 1;
        }
        c
    }
}

impl Storage for Coo {
    fn storage_bytes(&self) -> usize {
        self.len() * (4 + 4 + 4)
    }
    fn stored_entries(&self) -> usize {
        self.len()
    }
    fn nnz(&self) -> usize {
        self.vals.iter().filter(|v| **v != 0.0).count()
    }
}

impl SpMv for Coo {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// O(nnz) scan per row — COO is unsorted. Fine for the solve
    /// fallbacks and tests; serving converts to a row-addressable
    /// format before anything hot.
    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        for k in 0..self.len() {
            if self.rows[k] as usize == i {
                f(self.cols[k] as usize, self.vals[k]);
            }
        }
    }

    fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        y.fill(0.0);
        for i in 0..self.len() {
            y[self.rows[i] as usize] += self.vals[i] * x[self.cols[i] as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        // [[1, 0, 2], [0, 0, 0], [3, 4, 0]]
        let mut a = Coo::new(3, 3);
        a.push(0, 0, 1.0);
        a.push(2, 1, 4.0);
        a.push(0, 2, 2.0);
        a.push(2, 0, 3.0);
        a
    }

    #[test]
    fn spmv_matches_hand_computed() {
        let a = sample();
        let x = [1.0, 10.0, 100.0];
        let mut y = [0.0; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, [201.0, 0.0, 43.0]);
    }

    #[test]
    fn duplicates_accumulate() {
        let mut a = Coo::new(2, 2);
        a.push(0, 0, 1.5);
        a.push(0, 0, 2.5);
        let mut y = [0.0; 2];
        a.spmv(&[2.0, 0.0], &mut y);
        assert_eq!(y[0], 8.0);
    }

    #[test]
    fn sort_orders_rows_then_cols() {
        let mut a = sample();
        a.sort();
        let pairs: Vec<(u32, u32)> = a.rows.iter().copied().zip(a.cols.iter().copied()).collect();
        let mut sorted = pairs.clone();
        sorted.sort();
        assert_eq!(pairs, sorted);
    }

    #[test]
    fn row_counts_and_nnz() {
        let a = sample();
        assert_eq!(a.row_counts(), vec![2, 0, 2]);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.stored_entries(), 4);
        assert_eq!(a.storage_bytes(), 4 * 12);
    }
}
