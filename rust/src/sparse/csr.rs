//! CSR (compressed sparse row) — the paper's default format (§5.2) and
//! the baseline every optimization mode is compared against.

use super::{Storage, SpMv};

/// CSR sparse matrix: `row_ptr[i]..row_ptr[i+1]` spans row `i`'s entries.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Build directly from parts; validates the row_ptr invariants.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        row_ptr: Vec<u32>,
        cols: Vec<u32>,
        vals: Vec<f32>,
    ) -> Self {
        assert_eq!(row_ptr.len(), n_rows + 1, "row_ptr must have n_rows+1 entries");
        assert_eq!(*row_ptr.last().unwrap() as usize, vals.len());
        assert_eq!(cols.len(), vals.len());
        debug_assert!(row_ptr.windows(2).all(|w| w[0] <= w[1]), "row_ptr must be monotone");
        Csr { n_rows, n_cols, row_ptr, cols, vals }
    }

    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        (&self.cols[a..b], &self.vals[a..b])
    }

    pub fn row_len(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    /// Expand into the kernel-side COO triplets (vals, rows, cols), padded
    /// with (0.0, 0, 0) to `nnz_pad` — the exact input layout of the CSR
    /// Pallas kernel (`python/compile/kernels/csr.py`).
    pub fn to_kernel_coo(&self, nnz_pad: usize) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
        let nnz = self.vals.len();
        assert!(nnz_pad >= nnz, "nnz_pad {nnz_pad} < nnz {nnz}");
        let mut vals = Vec::with_capacity(nnz_pad);
        let mut rows = Vec::with_capacity(nnz_pad);
        let mut cols = Vec::with_capacity(nnz_pad);
        for i in 0..self.n_rows {
            let (a, b) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for k in a..b {
                vals.push(self.vals[k]);
                rows.push(i as i32);
                cols.push(self.cols[k] as i32);
            }
        }
        vals.resize(nnz_pad, 0.0);
        rows.resize(nnz_pad, 0);
        cols.resize(nnz_pad, 0);
        (vals, rows, cols)
    }

    /// Maximum row length (ELL width of this matrix).
    pub fn max_row_len(&self) -> usize {
        (0..self.n_rows).map(|i| self.row_len(i)).max().unwrap_or(0)
    }
}

impl Storage for Csr {
    fn storage_bytes(&self) -> usize {
        (self.n_rows + 1) * 4 + self.vals.len() * (4 + 4)
    }
    fn stored_entries(&self) -> usize {
        self.vals.len()
    }
    fn nnz(&self) -> usize {
        self.vals.iter().filter(|v| **v != 0.0).count()
    }
}

impl SpMv for Csr {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        let (a, b) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        for k in a..b {
            f(self.cols[k] as usize, self.vals[k]);
        }
    }

    fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for i in 0..self.n_rows {
            let (a, b) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            let mut acc = 0.0f32;
            for k in a..b {
                acc += self.vals[k] * x[self.cols[k] as usize];
            }
            y[i] = acc;
        }
    }

    /// SpMM override: streams the row arrays once for the whole batch,
    /// keeping the per-(row, vector) accumulation order identical to
    /// [`Csr::spmv`] so results stay bit-identical to independent
    /// products.
    fn spmm(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        for x in xs {
            assert_eq!(x.len(), self.n_cols);
        }
        let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; self.n_rows]).collect();
        for i in 0..self.n_rows {
            let (a, b) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                let mut acc = 0.0f32;
                for k in a..b {
                    acc += self.vals[k] * x[self.cols[k] as usize];
                }
                y[i] = acc;
            }
        }
        ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1, 0, 2], [0, 0, 0], [3, 4, 0]]
        Csr::new(3, 3, vec![0, 2, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0])
    }

    #[test]
    fn spmv_matches_hand_computed() {
        let a = sample();
        let x = [1.0, 10.0, 100.0];
        let mut y = [0.0; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, [201.0, 0.0, 43.0]);
    }

    #[test]
    fn row_access() {
        let a = sample();
        assert_eq!(a.row(0), (&[0u32, 2][..], &[1.0f32, 2.0][..]));
        assert_eq!(a.row_len(1), 0);
        assert_eq!(a.max_row_len(), 2);
    }

    #[test]
    fn kernel_coo_expansion_padded() {
        let a = sample();
        let (v, r, c) = a.to_kernel_coo(6);
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0]);
        assert_eq!(r, vec![0, 0, 2, 2, 0, 0]);
        assert_eq!(c, vec![0, 2, 0, 1, 0, 0]);
    }

    #[test]
    #[should_panic]
    fn kernel_coo_pad_too_small_panics() {
        sample().to_kernel_coo(3);
    }

    #[test]
    #[should_panic]
    fn bad_row_ptr_rejected() {
        Csr::new(2, 2, vec![0, 1], vec![0], vec![1.0]);
    }
}
