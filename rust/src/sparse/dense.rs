//! Dense matrix — the correctness anchor every sparse format is tested
//! against (paper Fig. 2a shows why it is *not* a serving format: zeros
//! are stored and multiplied).

use super::{Storage, SpMv};

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    pub n_rows: usize,
    pub n_cols: usize,
    pub data: Vec<f32>,
}

impl Dense {
    pub fn zero(n_rows: usize, n_cols: usize) -> Self {
        Dense { n_rows, n_cols, data: vec![0.0; n_rows * n_cols] }
    }

    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let n_rows = rows.len();
        let n_cols = rows.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(n_rows * n_cols);
        for r in rows {
            assert_eq!(r.len(), n_cols);
            data.extend_from_slice(r);
        }
        Dense { n_rows, n_cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.n_cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.n_cols + c]
    }
}

impl Storage for Dense {
    fn storage_bytes(&self) -> usize {
        self.data.len() * 4
    }
    fn stored_entries(&self) -> usize {
        self.data.len()
    }
    fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }
}

impl SpMv for Dense {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Dense stores every entry, so every column is visited — explicit
    /// zeros included (a zero diagonal must still read as singular).
    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        let row = &self.data[i * self.n_cols..(i + 1) * self.n_cols];
        for (c, v) in row.iter().enumerate() {
            f(c, *v);
        }
    }

    fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for r in 0..self.n_rows {
            let row = &self.data[r * self.n_cols..(r + 1) * self.n_cols];
            y[r] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmv_identity() {
        let mut a = Dense::zero(3, 3);
        for i in 0..3 {
            *a.at_mut(i, i) = 1.0;
        }
        let x = [7.0, -2.0, 0.5];
        let mut y = [0.0; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn from_rows_layout() {
        let a = Dense::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.at(1, 0), 3.0);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.storage_bytes(), 16);
    }
}
