//! SELL (sliced ELLPACK) format — ELL applied per slice of `h` rows, each
//! slice padded only to its own max row length (paper §2.3, Fig. 2e).
//! Suits matrices with strongly varying row lengths (power-law graphs):
//! zero-padding is confined to the slice, not the whole matrix.

use super::{Storage, SpMv};

/// Sliced-ELL sparse matrix with ragged per-slice storage.
#[derive(Debug, Clone, PartialEq)]
pub struct Sell {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Slice height (rows per slice).
    pub h: usize,
    /// Per-slice padded width (max row length inside the slice).
    pub slice_width: Vec<u32>,
    /// Start offset of each slice in `vals`/`cols` (len = n_slices + 1).
    /// Slice s spans `slice_ptr[s] .. slice_ptr[s+1]` = `h * slice_width[s]`
    /// entries, stored row-major within the slice.
    pub slice_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Sell {
    pub fn n_slices(&self) -> usize {
        self.slice_width.len()
    }

    /// Entries of (slice s, local row i): returns (cols, vals) slices.
    pub fn slice_row(&self, s: usize, i: usize) -> (&[u32], &[f32]) {
        let w = self.slice_width[s] as usize;
        let base = self.slice_ptr[s] as usize + i * w;
        (&self.cols[base..base + w], &self.vals[base..base + w])
    }

    /// Maximum slice width — the bucket width the AOT kernel needs.
    pub fn max_slice_width(&self) -> usize {
        self.slice_width.iter().map(|&w| w as usize).max().unwrap_or(0)
    }

    /// Marshal into the Pallas SELL kernel layout: data/cols `(ns_pad, h,
    /// w_pad)` with every slice padded to the common bucket width.
    pub fn to_kernel(&self, ns_pad: usize, w_pad: usize) -> (Vec<f32>, Vec<i32>) {
        let ns = self.n_slices();
        assert!(ns_pad >= ns && w_pad >= self.max_slice_width());
        let mut data = vec![0.0f32; ns_pad * self.h * w_pad];
        let mut cols = vec![0i32; ns_pad * self.h * w_pad];
        for s in 0..ns {
            let w = self.slice_width[s] as usize;
            for i in 0..self.h {
                let (rc, rv) = self.slice_row(s, i);
                let dst = (s * self.h + i) * w_pad;
                for j in 0..w {
                    data[dst + j] = rv[j];
                    cols[dst + j] = rc[j] as i32;
                }
            }
        }
        (data, cols)
    }

    /// Padding efficiency: nnz / stored entries (1.0 = no padding waste).
    pub fn fill_ratio(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.nnz() as f64 / self.vals.len() as f64
    }
}

impl Storage for Sell {
    fn storage_bytes(&self) -> usize {
        self.slice_width.len() * 4 + self.slice_ptr.len() * 4 + self.vals.len() * (4 + 4)
    }
    fn stored_entries(&self) -> usize {
        self.vals.len()
    }
    fn nnz(&self) -> usize {
        self.vals.iter().filter(|v| **v != 0.0).count()
    }
}

impl SpMv for Sell {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        let (cols, vals) = self.slice_row(i / self.h, i % self.h);
        for (c, v) in cols.iter().zip(vals) {
            f(*c as usize, *v);
        }
    }

    fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for s in 0..self.n_slices() {
            let w = self.slice_width[s] as usize;
            let base = self.slice_ptr[s] as usize;
            for i in 0..self.h {
                let r = s * self.h + i;
                if r >= self.n_rows {
                    break;
                }
                let rb = base + i * w;
                let mut acc = 0.0f32;
                for j in 0..w {
                    acc += self.vals[rb + j] * x[self.cols[rb + j] as usize];
                }
                y[r] = acc;
            }
        }
    }

    /// SpMM override: each ragged slice row is walked once and reduced
    /// against every vector in the batch. Per vector the in-row j order
    /// matches [`Sell::spmv`] exactly, so results are bit-identical to
    /// independent products.
    fn spmm(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        for x in xs {
            assert_eq!(x.len(), self.n_cols);
        }
        let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; self.n_rows]).collect();
        for s in 0..self.n_slices() {
            let w = self.slice_width[s] as usize;
            let base = self.slice_ptr[s] as usize;
            for i in 0..self.h {
                let r = s * self.h + i;
                if r >= self.n_rows {
                    break;
                }
                let rb = base + i * w;
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    let mut acc = 0.0f32;
                    for j in 0..w {
                        acc += self.vals[rb + j] * x[self.cols[rb + j] as usize];
                    }
                    y[r] = acc;
                }
            }
        }
        ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4x4, h = 2. Slice 0 rows {0:[.(0)=1], 1:[]} width 1;
    /// slice 1 rows {2:[(1)=2,(3)=3], 3:[(0)=4]} width 2.
    fn sample() -> Sell {
        Sell {
            n_rows: 4,
            n_cols: 4,
            h: 2,
            slice_width: vec![1, 2],
            slice_ptr: vec![0, 2, 6],
            cols: vec![0, 0, 1, 3, 0, 0],
            vals: vec![1.0, 0.0, 2.0, 3.0, 4.0, 0.0],
        }
    }

    #[test]
    fn spmv_matches_hand_computed() {
        let a = sample();
        let x = [1.0, 10.0, 100.0, 1000.0];
        let mut y = [0.0; 4];
        a.spmv(&x, &mut y);
        assert_eq!(y, [1.0, 0.0, 3020.0, 4.0]);
    }

    #[test]
    fn slice_access() {
        let a = sample();
        assert_eq!(a.n_slices(), 2);
        assert_eq!(a.slice_row(1, 0), (&[1u32, 3][..], &[2.0f32, 3.0][..]));
        assert_eq!(a.max_slice_width(), 2);
    }

    #[test]
    fn kernel_marshalling_pads_slices_to_common_width() {
        let a = sample();
        let (data, cols) = a.to_kernel(2, 3);
        // slice 0 row 0: [1, 0, 0]
        assert_eq!(&data[0..3], &[1.0, 0.0, 0.0]);
        // slice 1 row 0: [2, 3, 0] with cols [1, 3, 0]
        assert_eq!(&data[6..9], &[2.0, 3.0, 0.0]);
        assert_eq!(&cols[6..9], &[1, 3, 0]);
    }

    #[test]
    fn storage_less_than_global_ell_for_skewed_rows() {
        // SELL's whole point: stored entries < n_rows * global max width.
        let a = sample();
        assert!(a.stored_entries() < 4 * 2);
        assert!((a.fill_ratio() - 4.0 / 6.0).abs() < 1e-12);
    }
}
