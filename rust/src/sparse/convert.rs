//! Conversions between sparse formats.
//!
//! The run-time optimization mode (paper §5.3) converts the COO input to
//! the predicted best format, so these conversions are on the measured
//! path: `c_latency` in Table 7 is the wall time of the functions below.
//! Every conversion is exact (no reordering of accumulation within a row
//! beyond column sort) and is property-tested for SpMV equivalence in
//! `rust/tests/sparse_props.rs`.

use super::{Bell, Coo, Csr, Dense, Ell, Format, Sell};

/// COO -> CSR. Entries are counted/placed in one pass each (no sort
/// needed); duplicates are preserved as separate entries (they accumulate
/// identically under SpMV).
pub fn coo_to_csr(a: &Coo) -> Csr {
    let nnz = a.len();
    let mut row_ptr = vec![0u32; a.n_rows + 1];
    for &r in &a.rows {
        row_ptr[r as usize + 1] += 1;
    }
    for i in 0..a.n_rows {
        row_ptr[i + 1] += row_ptr[i];
    }
    let mut cols = vec![0u32; nnz];
    let mut vals = vec![0.0f32; nnz];
    let mut next = row_ptr.clone();
    for i in 0..nnz {
        let r = a.rows[i] as usize;
        let dst = next[r] as usize;
        cols[dst] = a.cols[i];
        vals[dst] = a.vals[i];
        next[r] += 1;
    }
    Csr::new(a.n_rows, a.n_cols, row_ptr, cols, vals)
}

/// CSR -> COO (row-major order).
pub fn csr_to_coo(a: &Csr) -> Coo {
    let mut out = Coo::with_capacity(a.n_rows, a.n_cols, a.vals.len());
    for i in 0..a.n_rows {
        let (cs, vs) = a.row(i);
        for (c, v) in cs.iter().zip(vs) {
            out.push(i, *c as usize, *v);
        }
    }
    out
}

/// CSR -> ELL. Width = max row length; shorter rows padded with (0, col 0).
pub fn csr_to_ell(a: &Csr) -> Ell {
    let width = a.max_row_len();
    let mut out = Ell::zero(a.n_rows, a.n_cols, width);
    for i in 0..a.n_rows {
        let (cs, vs) = a.row(i);
        let base = i * width;
        out.cols[base..base + cs.len()].copy_from_slice(cs);
        out.vals[base..base + vs.len()].copy_from_slice(vs);
    }
    out
}

/// ELL -> CSR, dropping padding (zero-valued) entries.
pub fn ell_to_csr(a: &Ell) -> Csr {
    let mut row_ptr = vec![0u32; a.n_rows + 1];
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for i in 0..a.n_rows {
        for s in 0..a.width {
            let v = a.vals[a.idx(i, s)];
            if v != 0.0 {
                cols.push(a.cols[a.idx(i, s)]);
                vals.push(v);
            }
        }
        row_ptr[i + 1] = vals.len() as u32;
    }
    Csr::new(a.n_rows, a.n_cols, row_ptr, cols, vals)
}

/// CSR -> BELL with `bh x bw` blocks.
///
/// Scans each block-row for occupied block columns, then fills dense
/// payloads. `kb` = max occupied block-columns over block rows.
pub fn csr_to_bell(a: &Csr, bh: usize, bw: usize) -> Bell {
    assert!(bh > 0 && bw > 0);
    let nb = a.n_rows.div_ceil(bh);
    let nbc = a.n_cols.div_ceil(bw);

    // Pass 1: per block-row, the set of occupied block columns.
    let mut occupied: Vec<Vec<u32>> = vec![Vec::new(); nb];
    let mut seen = vec![u32::MAX; nbc]; // epoch marker per block column
    for ib in 0..nb {
        let r0 = ib * bh;
        let r1 = (r0 + bh).min(a.n_rows);
        for r in r0..r1 {
            let (cs, _) = a.row(r);
            for &c in cs {
                let bc = c as usize / bw;
                if seen[bc] != ib as u32 {
                    seen[bc] = ib as u32;
                    occupied[ib].push(bc as u32);
                }
            }
        }
        occupied[ib].sort_unstable();
    }
    let kb = occupied.iter().map(Vec::len).max().unwrap_or(0).max(1);

    // Pass 2: fill payloads.
    let mut out = Bell::zero(a.n_rows, a.n_cols, bh, bw, kb);
    // block column -> slot index within this block row
    let mut slot_of = vec![usize::MAX; nbc];
    for ib in 0..nb {
        for (slot, &bc) in occupied[ib].iter().enumerate() {
            slot_of[bc as usize] = slot;
            out.bcols[ib * kb + slot] = bc;
        }
        let r0 = ib * bh;
        let r1 = (r0 + bh).min(a.n_rows);
        for r in r0..r1 {
            let (cs, vs) = a.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                let bc = c as usize / bw;
                let slot = slot_of[bc];
                let base = (ib * kb + slot) * bh * bw;
                out.blocks[base + (r - r0) * bw + (c as usize % bw)] += v;
            }
        }
        for &bc in &occupied[ib] {
            slot_of[bc as usize] = usize::MAX;
        }
    }
    out
}

/// BELL -> CSR, dropping zero payload entries.
pub fn bell_to_csr(a: &Bell) -> Csr {
    let mut row_ptr = vec![0u32; a.n_rows + 1];
    let mut entries: Vec<(u32, f32)> = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for r in 0..a.n_rows {
        let ib = r / a.bh;
        let i = r % a.bh;
        entries.clear();
        for k in 0..a.kb {
            let col0 = a.bcols[ib * a.kb + k] as usize * a.bw;
            let blk = a.block_at(ib, k);
            for j in 0..a.bw {
                let v = blk[i * a.bw + j];
                if v != 0.0 && col0 + j < a.n_cols {
                    entries.push(((col0 + j) as u32, v));
                }
            }
        }
        entries.sort_unstable_by_key(|e| e.0);
        for &(c, v) in entries.iter() {
            cols.push(c);
            vals.push(v);
        }
        row_ptr[r + 1] = vals.len() as u32;
    }
    Csr::new(a.n_rows, a.n_cols, row_ptr, cols, vals)
}

/// CSR -> SELL with slice height `h`. Each slice padded to its own max
/// row length (never below 1 so empty slices keep addressable storage).
pub fn csr_to_sell(a: &Csr, h: usize) -> Sell {
    assert!(h > 0);
    let ns = a.n_rows.div_ceil(h);
    let mut slice_width = Vec::with_capacity(ns);
    let mut slice_ptr = vec![0u32; ns + 1];
    for s in 0..ns {
        let r0 = s * h;
        let r1 = (r0 + h).min(a.n_rows);
        let w = (r0..r1).map(|r| a.row_len(r)).max().unwrap_or(0).max(1);
        slice_width.push(w as u32);
        slice_ptr[s + 1] = slice_ptr[s] + (h * w) as u32;
    }
    let total = slice_ptr[ns] as usize;
    let mut cols = vec![0u32; total];
    let mut vals = vec![0.0f32; total];
    for s in 0..ns {
        let w = slice_width[s] as usize;
        let base = slice_ptr[s] as usize;
        let r0 = s * h;
        for i in 0..h {
            let r = r0 + i;
            if r >= a.n_rows {
                break;
            }
            let (cs, vs) = a.row(r);
            let dst = base + i * w;
            cols[dst..dst + cs.len()].copy_from_slice(cs);
            vals[dst..dst + vs.len()].copy_from_slice(vs);
        }
    }
    Sell { n_rows: a.n_rows, n_cols: a.n_cols, h, slice_width, slice_ptr, cols, vals }
}

/// SELL -> CSR, dropping padding entries.
pub fn sell_to_csr(a: &Sell) -> Csr {
    let mut row_ptr = vec![0u32; a.n_rows + 1];
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for r in 0..a.n_rows {
        let s = r / a.h;
        let i = r % a.h;
        let (cs, vs) = a.slice_row(s, i);
        for (&c, &v) in cs.iter().zip(vs) {
            if v != 0.0 {
                cols.push(c);
                vals.push(v);
            }
        }
        row_ptr[r + 1] = vals.len() as u32;
    }
    Csr::new(a.n_rows, a.n_cols, row_ptr, cols, vals)
}

/// CSR -> dense (test/debug aid; O(n*m) memory).
pub fn csr_to_dense(a: &Csr) -> Dense {
    let mut d = Dense::zero(a.n_rows, a.n_cols);
    for r in 0..a.n_rows {
        let (cs, vs) = a.row(r);
        for (&c, &v) in cs.iter().zip(vs) {
            *d.at_mut(r, c as usize) += v;
        }
    }
    d
}

/// Convert CSR into any of the four kernel formats, with the paper's
/// default structural parameters (BELL 8x8 blocks, SELL slice height 32 —
/// overridable through [`ConvertParams`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvertParams {
    pub bell_bh: usize,
    pub bell_bw: usize,
    pub sell_h: usize,
}

impl Default for ConvertParams {
    fn default() -> Self {
        ConvertParams { bell_bh: 8, bell_bw: 8, sell_h: 32 }
    }
}

/// A matrix held in one of the four kernel formats.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyFormat {
    Csr(Csr),
    Ell(Ell),
    Bell(Bell),
    Sell(Sell),
}

impl AnyFormat {
    pub fn format(&self) -> Format {
        match self {
            AnyFormat::Csr(_) => Format::Csr,
            AnyFormat::Ell(_) => Format::Ell,
            AnyFormat::Bell(_) => Format::Bell,
            AnyFormat::Sell(_) => Format::Sell,
        }
    }

    pub fn as_spmv(&self) -> &dyn super::SpMv {
        match self {
            AnyFormat::Csr(m) => m,
            AnyFormat::Ell(m) => m,
            AnyFormat::Bell(m) => m,
            AnyFormat::Sell(m) => m,
        }
    }

    pub fn storage_bytes(&self) -> usize {
        use super::Storage;
        match self {
            AnyFormat::Csr(m) => m.storage_bytes(),
            AnyFormat::Ell(m) => m.storage_bytes(),
            AnyFormat::Bell(m) => m.storage_bytes(),
            AnyFormat::Sell(m) => m.storage_bytes(),
        }
    }
}

/// Convert a CSR matrix into `target` format.
pub fn convert(a: &Csr, target: Format, p: ConvertParams) -> AnyFormat {
    match target {
        Format::Csr => AnyFormat::Csr(a.clone()),
        Format::Ell => AnyFormat::Ell(csr_to_ell(a)),
        Format::Bell => AnyFormat::Bell(csr_to_bell(a, p.bell_bh, p.bell_bw)),
        Format::Sell => AnyFormat::Sell(csr_to_sell(a, p.sell_h)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SpMv;

    fn sample_coo() -> Coo {
        // 5x6 with skewed rows
        let mut a = Coo::new(5, 6);
        for (r, c, v) in [
            (0, 0, 1.0),
            (0, 5, 2.0),
            (1, 2, 3.0),
            (3, 0, 4.0),
            (3, 1, 5.0),
            (3, 2, 6.0),
            (3, 5, 7.0),
            (4, 4, 8.0),
        ] {
            a.push(r, c, v);
        }
        a
    }

    fn spmv_equal(a: &dyn SpMv, b: &dyn SpMv, x: &[f32]) {
        let (mut ya, mut yb) = (vec![0.0; a.n_rows()], vec![0.0; b.n_rows()]);
        a.spmv(x, &mut ya);
        b.spmv(x, &mut yb);
        for (p, q) in ya.iter().zip(&yb) {
            assert!((p - q).abs() < 1e-4, "{p} != {q}");
        }
    }

    #[test]
    fn coo_csr_roundtrip() {
        let coo = sample_coo();
        let csr = coo_to_csr(&coo);
        assert_eq!(csr.row_ptr, vec![0, 2, 3, 3, 7, 8]);
        let back = csr_to_coo(&csr);
        let csr2 = coo_to_csr(&back);
        assert_eq!(csr, csr2);
    }

    #[test]
    fn all_formats_spmv_equivalent() {
        let csr = coo_to_csr(&sample_coo());
        let x: Vec<f32> = (0..6).map(|i| (i as f32 + 1.0) * 0.5).collect();
        let p = ConvertParams { bell_bh: 2, bell_bw: 2, sell_h: 2 };
        for f in Format::ALL {
            let m = convert(&csr, f, p);
            spmv_equal(&csr, m.as_spmv(), &x);
        }
    }

    #[test]
    fn ell_round_trip_preserves_csr() {
        let csr = coo_to_csr(&sample_coo());
        assert_eq!(ell_to_csr(&csr_to_ell(&csr)), csr);
    }

    #[test]
    fn sell_round_trip_preserves_csr() {
        let csr = coo_to_csr(&sample_coo());
        assert_eq!(sell_to_csr(&csr_to_sell(&csr, 2)), csr);
    }

    #[test]
    fn bell_round_trip_preserves_values() {
        let csr = coo_to_csr(&sample_coo());
        let back = bell_to_csr(&csr_to_bell(&csr, 2, 2));
        // same dense realization
        assert_eq!(csr_to_dense(&back).data, csr_to_dense(&csr).data);
    }

    #[test]
    fn sell_pads_less_than_ell_on_skewed_matrix() {
        use crate::sparse::Storage;
        let csr = coo_to_csr(&sample_coo());
        let ell = csr_to_ell(&csr);
        let sell = csr_to_sell(&csr, 2);
        assert!(sell.stored_entries() < ell.stored_entries());
    }

    #[test]
    fn bell_merges_duplicates_into_payload() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        let bell = csr_to_bell(&coo_to_csr(&coo), 2, 2);
        assert_eq!(bell.block_at(0, 0)[0], 3.0);
    }

    #[test]
    fn dense_matches_csr() {
        let csr = coo_to_csr(&sample_coo());
        let d = csr_to_dense(&csr);
        let x = vec![1.0; 6];
        spmv_equal(&csr, &d, &x);
    }

    #[test]
    fn empty_matrix_converts_everywhere() {
        let coo = Coo::new(3, 3);
        let csr = coo_to_csr(&coo);
        for f in Format::ALL {
            let m = convert(&csr, f, ConvertParams::default());
            let y = m.as_spmv().spmv_alloc(&[1.0, 1.0, 1.0]);
            assert_eq!(y, vec![0.0; 3]);
        }
    }
}
