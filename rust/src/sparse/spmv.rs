//! The SpMV operation trait: `y = A x` for every storage format.

/// Sparse (or dense) matrix-vector product.
pub trait SpMv {
    fn n_rows(&self) -> usize;
    fn n_cols(&self) -> usize;

    /// Compute `y = A x`. `y` is fully overwritten.
    fn spmv(&self, x: &[f32], y: &mut [f32]);

    /// Allocate-and-return convenience wrapper.
    fn spmv_alloc(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.n_rows()];
        self.spmv(x, &mut y);
        y
    }

    /// FLOPs of one product (2 per stored multiply-add on real non-zeros) —
    /// the numerator of the paper's MFLOPS/Watt objective (§6.3).
    fn flops(&self, nnz: usize) -> u64 {
        2 * nnz as u64
    }
}

#[cfg(test)]
mod tests {
    use crate::sparse::{Coo, SpMv};

    #[test]
    fn spmv_alloc_matches_spmv() {
        let mut a = Coo::new(2, 2);
        a.push(0, 1, 3.0);
        let x = [2.0, 5.0];
        let mut y = [0.0; 2];
        a.spmv(&x, &mut y);
        assert_eq!(a.spmv_alloc(&x), y.to_vec());
    }

    #[test]
    fn flops_counts_two_per_nnz() {
        let a = Coo::new(1, 1);
        assert_eq!(a.flops(10), 20);
    }
}
