//! The SpMV operation trait: `y = A x` for every storage format, plus
//! the batched SpMM entry point `Y = A X` the serving pool dispatches
//! coalesced request groups through.

/// Sparse (or dense) matrix-vector product.
pub trait SpMv {
    fn n_rows(&self) -> usize;
    fn n_cols(&self) -> usize;

    /// Compute `y = A x`. `y` is fully overwritten.
    fn spmv(&self, x: &[f32], y: &mut [f32]);

    /// Allocate-and-return convenience wrapper.
    fn spmv_alloc(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.n_rows()];
        self.spmv(x, &mut y);
        y
    }

    /// Compute `y_j = A x_j` for a batch of input vectors against one
    /// resident matrix — true SpMM, the throughput lever the serving
    /// pool's request coalescing dispatches through. The contract is
    /// bit-identical results to `spmv_alloc` on each vector (same
    /// accumulation order per output element), so batched and unbatched
    /// serving paths are interchangeable. Every concrete format
    /// (CSR/ELL/BELL/SELL) overrides this to walk its matrix arrays
    /// ONCE for the whole batch; the default is the per-vector loop for
    /// formats without a streaming advantage (COO, dense).
    ///
    /// Takes borrowed slices (not owned `Vec`s) so the serving queue's
    /// shared `Arc<[f32]>` payloads batch without a per-request copy;
    /// the trait stays object-safe for `dyn SpMv` dispatch.
    fn spmm(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.spmv_alloc(x)).collect()
    }

    /// Historical name of [`SpMv::spmm`] (pre-SpMM serving called the
    /// batched dispatch `spmv_batch`); kept as a delegating alias so
    /// existing callers keep working. Override `spmm`, not this.
    fn spmv_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        self.spmm(xs)
    }

    /// FLOPs of one product (2 per stored multiply-add on real non-zeros) —
    /// the numerator of the paper's MFLOPS/Watt objective (§6.3).
    fn flops(&self, nnz: usize) -> u64 {
        2 * nnz as u64
    }
}

#[cfg(test)]
mod tests {
    use crate::sparse::{Coo, SpMv};

    #[test]
    fn spmv_alloc_matches_spmv() {
        let mut a = Coo::new(2, 2);
        a.push(0, 1, 3.0);
        let x = [2.0, 5.0];
        let mut y = [0.0; 2];
        a.spmv(&x, &mut y);
        assert_eq!(a.spmv_alloc(&x), y.to_vec());
    }

    #[test]
    fn flops_counts_two_per_nnz() {
        let a = Coo::new(1, 1);
        assert_eq!(a.flops(10), 20);
    }

    #[test]
    fn default_spmm_matches_individual_products() {
        let mut a = Coo::new(3, 2);
        a.push(0, 0, 2.0);
        a.push(2, 1, -1.5);
        let xs: Vec<&[f32]> = vec![&[1.0, 2.0], &[-3.0, 0.5]];
        let ys = a.spmm(&xs);
        assert_eq!(ys.len(), 2);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, a.spmv_alloc(x));
        }
        // the legacy alias routes through spmm
        assert_eq!(a.spmv_batch(&xs), ys);
    }

    #[test]
    fn empty_batch_is_empty() {
        let a = Coo::new(2, 2);
        assert!(a.spmm(&[]).is_empty());
    }
}
