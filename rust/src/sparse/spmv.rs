//! The SpMV operation trait: `y = A x` for every storage format, plus
//! the batched SpMM entry point `Y = A X` the serving pool dispatches
//! coalesced request groups through, and the solver-side kernel classes
//! (SpTRSV triangular solve, SymGS sweep) built on per-row traversal.

/// Sparse (or dense) matrix-vector product.
pub trait SpMv {
    fn n_rows(&self) -> usize;
    fn n_cols(&self) -> usize;

    /// Visit every *stored* entry `(col, val)` of row `i`, padding
    /// included, in the format's storage order. This is the one
    /// format-specific primitive the solve kernels (SpTRSV, SymGS) are
    /// built on: the provided methods gather a row through it and sort
    /// by column, so solves are bit-identical across formats by
    /// construction regardless of how a format orders a row internally
    /// (COO is unsorted, BELL is block-major).
    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32));

    /// Compute `y = A x`. `y` is fully overwritten.
    fn spmv(&self, x: &[f32], y: &mut [f32]);

    /// Allocate-and-return convenience wrapper.
    fn spmv_alloc(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.n_rows()];
        self.spmv(x, &mut y);
        y
    }

    /// Compute `y_j = A x_j` for a batch of input vectors against one
    /// resident matrix — true SpMM, the throughput lever the serving
    /// pool's request coalescing dispatches through. The contract is
    /// bit-identical results to `spmv_alloc` on each vector (same
    /// accumulation order per output element), so batched and unbatched
    /// serving paths are interchangeable. Every concrete format
    /// (CSR/ELL/BELL/SELL) overrides this to walk its matrix arrays
    /// ONCE for the whole batch; the default is the per-vector loop for
    /// formats without a streaming advantage (COO, dense).
    ///
    /// Takes borrowed slices (not owned `Vec`s) so the serving queue's
    /// shared `Arc<[f32]>` payloads batch without a per-request copy;
    /// the trait stays object-safe for `dyn SpMv` dispatch.
    fn spmm(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.spmv_alloc(x)).collect()
    }

    /// Historical name of [`SpMv::spmm`] (pre-SpMM serving called the
    /// batched dispatch `spmv_batch`); kept as a delegating alias so
    /// existing callers keep working. Override `spmm`, not this.
    fn spmv_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        self.spmm(xs)
    }

    /// FLOPs of one product (2 per stored multiply-add on real non-zeros) —
    /// the numerator of the paper's MFLOPS/Watt objective (§6.3).
    fn flops(&self, nnz: usize) -> u64 {
        2 * nnz as u64
    }

    /// Sparse triangular solve: `x` such that `T x = b`, where `T` is
    /// the lower (`lower = true`, unit stride forward) or upper
    /// (backward) triangle of `self` *including the diagonal*. Stored
    /// entries strictly on the wrong side of the diagonal are ignored,
    /// so a full matrix solves with its triangle HPCG-style. Rows are
    /// gathered via [`SpMv::for_each_in_row`] and accumulated in
    /// ascending-column order, making the result bit-identical across
    /// every storage format (padding contributes exact zeros).
    ///
    /// Errors when the matrix is not square, `b` has the wrong length,
    /// or any row lacks a nonzero diagonal (the singular case — padding
    /// entries carry value 0.0 and can never fake a pivot).
    fn sptrsv(&self, b: &[f32], lower: bool) -> anyhow::Result<Vec<f32>> {
        let n = self.n_rows();
        anyhow::ensure!(
            self.n_cols() == n,
            "sptrsv needs a square matrix, got {}x{}",
            n,
            self.n_cols()
        );
        anyhow::ensure!(b.len() == n, "sptrsv rhs length {} != n {}", b.len(), n);
        let mut x = vec![0.0f32; n];
        let mut row: Vec<(usize, f32)> = Vec::new();
        for step in 0..n {
            let i = if lower { step } else { n - 1 - step };
            let diag = gather_row(self, i, &mut row)?;
            let mut acc = b[i];
            for &(c, v) in &row {
                let in_triangle = if lower { c < i } else { c > i };
                if in_triangle {
                    acc -= v * x[c];
                }
            }
            x[i] = acc / diag;
        }
        Ok(x)
    }

    /// One symmetric Gauss-Seidel sweep on `A x = b`: a forward pass
    /// (rows ascending) then a backward pass (rows descending), each
    /// updating `x[i] = (b[i] - sum_{j != i} a_ij x[j]) / a_ii` in place
    /// with the latest values. Applying one sweep from `x = 0` is the
    /// standard SymGS preconditioner/smoother (multigrid, CG). Same
    /// gather-and-sort row traversal as [`SpMv::sptrsv`], so sweeps are
    /// bit-identical across formats; same singular-diagonal error.
    fn symgs_sweep(&self, b: &[f32], x: &mut [f32]) -> anyhow::Result<()> {
        let n = self.n_rows();
        anyhow::ensure!(
            self.n_cols() == n,
            "symgs needs a square matrix, got {}x{}",
            n,
            self.n_cols()
        );
        anyhow::ensure!(b.len() == n, "symgs rhs length {} != n {}", b.len(), n);
        anyhow::ensure!(x.len() == n, "symgs iterate length {} != n {}", x.len(), n);
        let mut row: Vec<(usize, f32)> = Vec::new();
        for pass in 0..2 {
            for step in 0..n {
                let i = if pass == 0 { step } else { n - 1 - step };
                let diag = gather_row(self, i, &mut row)?;
                let mut acc = b[i];
                for &(c, v) in &row {
                    if c != i {
                        acc -= v * x[c];
                    }
                }
                x[i] = acc / diag;
            }
        }
        Ok(())
    }
}

/// Gather row `i` into `row` sorted by column (stable, padding first at
/// col 0) and return its diagonal pivot. Shared by the provided solve
/// methods; the sort is what buys cross-format bit-identity.
fn gather_row<M: SpMv + ?Sized>(
    m: &M,
    i: usize,
    row: &mut Vec<(usize, f32)>,
) -> anyhow::Result<f32> {
    row.clear();
    m.for_each_in_row(i, &mut |c, v| row.push((c, v)));
    row.sort_by_key(|&(c, _)| c);
    let mut diag = 0.0f32;
    for &(c, v) in row.iter() {
        if c == i && v != 0.0 {
            diag = v;
        }
    }
    anyhow::ensure!(
        diag != 0.0,
        "singular system: row {i} has no nonzero diagonal entry"
    );
    Ok(diag)
}

#[cfg(test)]
mod tests {
    use crate::sparse::{Coo, SpMv};

    #[test]
    fn spmv_alloc_matches_spmv() {
        let mut a = Coo::new(2, 2);
        a.push(0, 1, 3.0);
        let x = [2.0, 5.0];
        let mut y = [0.0; 2];
        a.spmv(&x, &mut y);
        assert_eq!(a.spmv_alloc(&x), y.to_vec());
    }

    #[test]
    fn flops_counts_two_per_nnz() {
        let a = Coo::new(1, 1);
        assert_eq!(a.flops(10), 20);
    }

    #[test]
    fn default_spmm_matches_individual_products() {
        let mut a = Coo::new(3, 2);
        a.push(0, 0, 2.0);
        a.push(2, 1, -1.5);
        let xs: Vec<&[f32]> = vec![&[1.0, 2.0], &[-3.0, 0.5]];
        let ys = a.spmm(&xs);
        assert_eq!(ys.len(), 2);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, a.spmv_alloc(x));
        }
        // the legacy alias routes through spmm
        assert_eq!(a.spmv_batch(&xs), ys);
    }

    #[test]
    fn empty_batch_is_empty() {
        let a = Coo::new(2, 2);
        assert!(a.spmm(&[]).is_empty());
    }
}
