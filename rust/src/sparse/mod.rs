//! Sparse matrix substrate: the four formats the paper evaluates
//! (CSR, ELL, BELL, SELL — §2.3), plus COO (SuiteSparse's on-disk default,
//! §7.5) and dense, with all conversions and per-format CPU SpMV kernels.
//!
//! Conventions (shared with `python/compile/kernels/ref.py`):
//! * values are `f32`, indices `u32`;
//! * padding entries carry value `0.0` and column index `0`, so SpMV over
//!   padded storage is exact without masking;
//! * all formats implement [`SpMv`] and report their storage footprint via
//!   [`Storage`] (used by the simulator's memory-traffic model and by the
//!   conversion-overhead model of §7.5).

pub mod bell;
pub mod convert;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod ell;
pub mod sell;
pub mod spmv;

pub use bell::Bell;
pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use ell::Ell;
pub use sell::Sell;
pub use spmv::SpMv;

/// The four kernel formats of the paper, in its order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Format {
    Csr,
    Ell,
    Bell,
    Sell,
}

impl Format {
    pub const ALL: [Format; 4] = [Format::Csr, Format::Ell, Format::Bell, Format::Sell];

    pub fn name(self) -> &'static str {
        match self {
            Format::Csr => "csr",
            Format::Ell => "ell",
            Format::Bell => "bell",
            Format::Sell => "sell",
        }
    }

    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "csr" | "CSR" => Some(Format::Csr),
            "ell" | "ELL" => Some(Format::Ell),
            "bell" | "BELL" => Some(Format::Bell),
            "sell" | "SELL" => Some(Format::Sell),
            _ => None,
        }
    }

    /// Stable class id used as the ML label for format selection.
    pub fn class_id(self) -> usize {
        match self {
            Format::Csr => 0,
            Format::Ell => 1,
            Format::Bell => 2,
            Format::Sell => 3,
        }
    }

    pub fn from_class_id(id: usize) -> Option<Format> {
        Format::ALL.get(id).copied()
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The kernel classes the pool serves. SpMV (`y = Ax`, incl. batched
/// SpMM) is the paper's subject; SpTRSV (sparse triangular solve) and
/// SymGS (one symmetric Gauss-Seidel sweep) are the solver-side kernels
/// real SpMV traffic is embedded in (CG preconditioning, multigrid
/// smoothing). Kind is part of the request class: the online loop keys
/// bandit buckets and per-arm attribution on it so solve evidence never
/// mixes with product evidence.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelKind {
    Spmv,
    Sptrsv,
    Symgs,
}

impl KernelKind {
    pub const ALL: [KernelKind; 3] = [KernelKind::Spmv, KernelKind::Sptrsv, KernelKind::Symgs];
    pub const N: usize = 3;

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Spmv => "spmv",
            KernelKind::Sptrsv => "sptrsv",
            KernelKind::Symgs => "symgs",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "spmv" => Some(KernelKind::Spmv),
            "sptrsv" => Some(KernelKind::Sptrsv),
            "symgs" => Some(KernelKind::Symgs),
            _ => None,
        }
    }

    /// Stable class id (bucket-key component and attribution stride).
    pub fn class_id(self) -> usize {
        match self {
            KernelKind::Spmv => 0,
            KernelKind::Sptrsv => 1,
            KernelKind::Symgs => 2,
        }
    }

    pub fn from_class_id(id: usize) -> Option<KernelKind> {
        KernelKind::ALL.get(id).copied()
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Storage accounting: bytes moved from DRAM when a kernel streams the
/// matrix once (the simulator's traffic model) and bytes resident.
pub trait Storage {
    /// Total bytes of the format's arrays (values + indices + pointers).
    fn storage_bytes(&self) -> usize;
    /// Number of *stored* entries including padding (>= nnz).
    fn stored_entries(&self) -> usize;
    /// Number of meaningful non-zeros represented.
    fn nnz(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_roundtrip_ids() {
        for f in Format::ALL {
            assert_eq!(Format::from_class_id(f.class_id()), Some(f));
            assert_eq!(Format::parse(f.name()), Some(f));
        }
        assert_eq!(Format::parse("hyb"), None);
        assert_eq!(Format::from_class_id(9), None);
    }

    #[test]
    fn format_display_matches_name() {
        assert_eq!(Format::Bell.to_string(), "bell");
    }

    #[test]
    fn kernel_kind_roundtrip_ids() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::from_class_id(k.class_id()), Some(k));
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("spmm"), None, "spmm is a manifest kind, not a request class");
        assert_eq!(KernelKind::from_class_id(KernelKind::N), None);
        assert_eq!(KernelKind::ALL.len(), KernelKind::N);
    }
}
