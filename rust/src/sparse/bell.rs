//! BELL (blocked ELLPACK) format — ELL over dense `bh x bw` blocks
//! (paper §2.3, Fig. 2d). Suits matrices whose non-zeros cluster into
//! blocks (FEM, multi-DOF meshes); wasteful when non-zeros are scattered.

use super::{Storage, SpMv};

/// Blocked-ELL sparse matrix.
///
/// `n_rows` is padded up to a multiple of `bh` at construction; blocks are
/// stored row-major as `(nb, kb)` with dense `bh*bw` payloads. Padding
/// blocks have `bcols == 0` and all-zero payloads.
#[derive(Debug, Clone, PartialEq)]
pub struct Bell {
    pub n_rows: usize,
    pub n_cols: usize,
    pub bh: usize,
    pub bw: usize,
    /// Number of block rows: ceil(n_rows / bh).
    pub nb: usize,
    /// Blocks stored per block-row (max over block rows).
    pub kb: usize,
    /// `(nb, kb)` block-column indices.
    pub bcols: Vec<u32>,
    /// `(nb, kb, bh, bw)` dense payloads.
    pub blocks: Vec<f32>,
}

impl Bell {
    pub fn zero(n_rows: usize, n_cols: usize, bh: usize, bw: usize, kb: usize) -> Self {
        let nb = n_rows.div_ceil(bh);
        Bell {
            n_rows,
            n_cols,
            bh,
            bw,
            nb,
            kb,
            bcols: vec![0; nb * kb],
            blocks: vec![0.0; nb * kb * bh * bw],
        }
    }

    #[inline]
    pub fn block_at(&self, ib: usize, k: usize) -> &[f32] {
        let base = (ib * self.kb + k) * self.bh * self.bw;
        &self.blocks[base..base + self.bh * self.bw]
    }

    #[inline]
    pub fn block_at_mut(&mut self, ib: usize, k: usize) -> &mut [f32] {
        let base = (ib * self.kb + k) * self.bh * self.bw;
        &mut self.blocks[base..base + self.bh * self.bw]
    }

    /// Number of block-columns the dense x vector spans.
    pub fn n_bcols(&self) -> usize {
        self.n_cols.div_ceil(self.bw)
    }

    /// Marshal into the Pallas BELL kernel layout: data `(nb_pad, kb_pad,
    /// bh, bw)` f32 and bcols `(nb_pad, kb_pad)` i32.
    pub fn to_kernel(&self, nb_pad: usize, kb_pad: usize) -> (Vec<f32>, Vec<i32>) {
        assert!(nb_pad >= self.nb && kb_pad >= self.kb);
        let bsz = self.bh * self.bw;
        let mut data = vec![0.0f32; nb_pad * kb_pad * bsz];
        let mut bcols = vec![0i32; nb_pad * kb_pad];
        for ib in 0..self.nb {
            for k in 0..self.kb {
                let dst = (ib * kb_pad + k) * bsz;
                data[dst..dst + bsz].copy_from_slice(self.block_at(ib, k));
                bcols[ib * kb_pad + k] = self.bcols[ib * self.kb + k] as i32;
            }
        }
        (data, bcols)
    }

    /// Fraction of stored block payload slots that hold real non-zeros.
    pub fn block_fill_ratio(&self) -> f64 {
        if self.blocks.is_empty() {
            return 0.0;
        }
        self.nnz() as f64 / self.blocks.len() as f64
    }
}

impl Storage for Bell {
    fn storage_bytes(&self) -> usize {
        self.bcols.len() * 4 + self.blocks.len() * 4
    }
    fn stored_entries(&self) -> usize {
        self.blocks.len()
    }
    fn nnz(&self) -> usize {
        self.blocks.iter().filter(|v| **v != 0.0).count()
    }
}

impl SpMv for Bell {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        let (ib, li) = (i / self.bh, i % self.bh);
        for k in 0..self.kb {
            let col0 = self.bcols[ib * self.kb + k] as usize * self.bw;
            let blk = self.block_at(ib, k);
            for j in 0..self.bw {
                let c = col0 + j;
                if c < self.n_cols {
                    f(c, blk[li * self.bw + j]);
                }
            }
        }
    }

    fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        y.fill(0.0);
        for ib in 0..self.nb {
            let row0 = ib * self.bh;
            for k in 0..self.kb {
                let col0 = self.bcols[ib * self.kb + k] as usize * self.bw;
                let blk = self.block_at(ib, k);
                for i in 0..self.bh {
                    let r = row0 + i;
                    if r >= self.n_rows {
                        break;
                    }
                    let mut acc = 0.0f32;
                    for j in 0..self.bw {
                        let c = col0 + j;
                        if c < self.n_cols {
                            acc += blk[i * self.bw + j] * x[c];
                        }
                    }
                    y[r] += acc;
                }
            }
        }
    }

    /// SpMM override: each dense block is loaded once and contracted
    /// against every vector in the batch before moving on. Per vector
    /// the (block-row, block, row) visit order — and therefore the
    /// accumulation order into `y[r]` — matches [`Bell::spmv`] exactly,
    /// so results are bit-identical to independent products.
    fn spmm(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        for x in xs {
            assert_eq!(x.len(), self.n_cols);
        }
        let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; self.n_rows]).collect();
        for ib in 0..self.nb {
            let row0 = ib * self.bh;
            for k in 0..self.kb {
                let col0 = self.bcols[ib * self.kb + k] as usize * self.bw;
                let blk = self.block_at(ib, k);
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    for i in 0..self.bh {
                        let r = row0 + i;
                        if r >= self.n_rows {
                            break;
                        }
                        let mut acc = 0.0f32;
                        for j in 0..self.bw {
                            let c = col0 + j;
                            if c < self.n_cols {
                                acc += blk[i * self.bw + j] * x[c];
                            }
                        }
                        y[r] += acc;
                    }
                }
            }
        }
        ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Bell {
        // 4x4 matrix, 2x2 blocks, kb = 1:
        // block-row 0 holds block at bcol 1: [[1,2],[3,4]] -> cols 2..4
        // block-row 1 holds block at bcol 0: [[5,0],[0,6]] -> cols 0..2
        let mut b = Bell::zero(4, 4, 2, 2, 1);
        b.bcols[0] = 1;
        b.block_at_mut(0, 0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.bcols[1] = 0;
        b.block_at_mut(1, 0).copy_from_slice(&[5.0, 0.0, 0.0, 6.0]);
        b
    }

    #[test]
    fn spmv_matches_hand_computed() {
        let b = sample();
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        b.spmv(&x, &mut y);
        // row0 = 1*3+2*4 = 11; row1 = 3*3+4*4 = 25; row2 = 5*1 = 5; row3 = 6*2 = 12
        assert_eq!(y, [11.0, 25.0, 5.0, 12.0]);
    }

    #[test]
    fn ragged_rows_handled() {
        // n_rows = 3 with bh = 2 -> nb = 2, last block row half-valid
        let mut b = Bell::zero(3, 4, 2, 2, 1);
        b.bcols[1] = 1;
        b.block_at_mut(1, 0).copy_from_slice(&[1.0, 1.0, 9.0, 9.0]); // row 3 dropped
        let mut y = [0.0; 3];
        b.spmv(&[1.0, 1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, [0.0, 0.0, 2.0]);
    }

    #[test]
    fn kernel_marshalling() {
        let b = sample();
        let (data, bcols) = b.to_kernel(2, 2);
        assert_eq!(bcols, vec![1, 0, 0, 0]);
        assert_eq!(&data[0..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&data[4..8], &[0.0; 4]); // padded block
    }

    #[test]
    fn fill_ratio() {
        let b = sample();
        assert_eq!(b.nnz(), 6);
        assert!((b.block_fill_ratio() - 6.0 / 8.0).abs() < 1e-12);
    }
}
