//! ELL (ELLPACK) format — fixed-width row storage (paper §2.3, Fig. 2c).
//!
//! Row-major layout `(n_rows, width)`; padding entries are `(val 0, col 0)`.
//! `ELL_ratio` (Table 2) = nnz / (n_rows * width): small when a few long
//! rows inflate the width — exactly the regime where ELL wastes compute.

use super::{Storage, SpMv};

/// ELLPACK sparse matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Ell {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Entries stored per row (max row length of the source matrix).
    pub width: usize,
    /// `n_rows * width`, row-major.
    pub cols: Vec<u32>,
    /// `n_rows * width`, row-major.
    pub vals: Vec<f32>,
}

impl Ell {
    pub fn new(n_rows: usize, n_cols: usize, width: usize, cols: Vec<u32>, vals: Vec<f32>) -> Self {
        assert_eq!(cols.len(), n_rows * width);
        assert_eq!(vals.len(), n_rows * width);
        Ell { n_rows, n_cols, width, cols, vals }
    }

    pub fn zero(n_rows: usize, n_cols: usize, width: usize) -> Self {
        Ell {
            n_rows,
            n_cols,
            width,
            cols: vec![0; n_rows * width],
            vals: vec![0.0; n_rows * width],
        }
    }

    #[inline]
    pub fn idx(&self, row: usize, slot: usize) -> usize {
        row * self.width + slot
    }

    /// Marshal into kernel-bucket arrays: pad rows to `rows_pad`, width to
    /// `width_pad` (the Pallas ELL kernel layout). Returns (vals, cols).
    pub fn to_kernel(&self, rows_pad: usize, width_pad: usize) -> (Vec<f32>, Vec<i32>) {
        assert!(rows_pad >= self.n_rows && width_pad >= self.width);
        let mut vals = vec![0.0f32; rows_pad * width_pad];
        let mut cols = vec![0i32; rows_pad * width_pad];
        for r in 0..self.n_rows {
            for s in 0..self.width {
                vals[r * width_pad + s] = self.vals[self.idx(r, s)];
                cols[r * width_pad + s] = self.cols[self.idx(r, s)] as i32;
            }
        }
        (vals, cols)
    }

    /// The paper's ELL_ratio feature: nnz / stored entries.
    pub fn fill_ratio(&self) -> f64 {
        if self.vals.is_empty() {
            return 0.0;
        }
        self.nnz() as f64 / self.vals.len() as f64
    }
}

impl Storage for Ell {
    fn storage_bytes(&self) -> usize {
        self.vals.len() * (4 + 4)
    }
    fn stored_entries(&self) -> usize {
        self.vals.len()
    }
    fn nnz(&self) -> usize {
        self.vals.iter().filter(|v| **v != 0.0).count()
    }
}

impl SpMv for Ell {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn for_each_in_row(&self, i: usize, f: &mut dyn FnMut(usize, f32)) {
        let base = i * self.width;
        for s in 0..self.width {
            f(self.cols[base + s] as usize, self.vals[base + s]);
        }
    }

    fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        for r in 0..self.n_rows {
            let base = r * self.width;
            let mut acc = 0.0f32;
            for s in 0..self.width {
                acc += self.vals[base + s] * x[self.cols[base + s] as usize];
            }
            y[r] = acc;
        }
    }

    /// SpMM override: streams each padded row once for the whole
    /// batch, with the same per-(row, vector) accumulation order as
    /// [`SpMv::spmv`] — bit-identical to independent products.
    fn spmm(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        for x in xs {
            assert_eq!(x.len(), self.n_cols);
        }
        let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| vec![0.0f32; self.n_rows]).collect();
        for r in 0..self.n_rows {
            let base = r * self.width;
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                let mut acc = 0.0f32;
                for s in 0..self.width {
                    acc += self.vals[base + s] * x[self.cols[base + s] as usize];
                }
                y[r] = acc;
            }
        }
        ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Ell {
        // [[1, 0, 2], [0, 0, 0], [3, 4, 0]] with width 2
        Ell::new(
            3,
            3,
            2,
            vec![0, 2, 0, 0, 0, 1],
            vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0],
        )
    }

    #[test]
    fn spmv_matches_hand_computed() {
        let a = sample();
        let x = [1.0, 10.0, 100.0];
        let mut y = [0.0; 3];
        a.spmv(&x, &mut y);
        assert_eq!(y, [201.0, 0.0, 43.0]);
    }

    #[test]
    fn fill_ratio_counts_padding() {
        let a = sample();
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.stored_entries(), 6);
        assert!((a.fill_ratio() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn kernel_marshalling_pads() {
        let a = sample();
        let (v, c) = a.to_kernel(4, 3);
        assert_eq!(v.len(), 12);
        assert_eq!(v[0..3], [1.0, 2.0, 0.0]);
        assert_eq!(c[0..3], [0, 2, 0]);
        assert_eq!(v[9..12], [0.0, 0.0, 0.0]); // padded row
    }

    #[test]
    fn zero_constructor() {
        let a = Ell::zero(2, 2, 3);
        assert_eq!(a.nnz(), 0);
        assert_eq!(a.stored_entries(), 6);
    }
}
