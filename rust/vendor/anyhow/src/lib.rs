//! Offline shim for the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io mirror, so this vendored crate
//! provides the exact subset of anyhow's API that auto_spmv uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! [`anyhow!`] / [`bail!`] macros. Error values carry a chain of context
//! frames; `{e}` prints the outermost message (anyhow's `Display`) and
//! `{e:#}` prints the whole chain separated by `: ` (anyhow's alternate
//! form). Replacing this with the real crate is a one-line Cargo.toml
//! change — no call sites need to move.

use std::fmt;

/// An error with a chain of context frames (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// The typed value this frame was built from via [`Error::new`], if
    /// any — what [`Error::downcast_ref`] recovers. Message-only frames
    /// (`anyhow!`, `.context(...)`) carry none.
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None, payload: None }
    }

    /// Construct from a typed std error, retaining the value so callers
    /// can recover it with [`Error::downcast_ref`] (anyhow's typed-error
    /// round-trip).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: None, payload: Some(Box::new(error)) }
    }

    /// Wrap this error in one more frame of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)), payload: None }
    }

    /// The typed error this chain was built from, if any frame holds an
    /// `E` (outermost first — matches anyhow, which searches the chain).
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(p) = e.payload.as_deref().and_then(|p| p.downcast_ref::<E>()) {
                return Some(p);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, `outer: inner: root`
            write!(f, "{}", self.chain().join(": "))
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// The reason anyhow::Error does not implement std::error::Error: this
// blanket conversion (which powers `?` on io/parse/... errors) would
// otherwise overlap with core's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std error's source chain into context frames.
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result` (over std error types) and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format_args!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format_args!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format_args!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tok:tt)*) => {
        return Err($crate::anyhow!($($tok)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tok:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tok)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        assert_eq!(format!("{}", none.context("absent").unwrap_err()), "absent");
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("absent").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(flag: bool) -> Result<u32> {
            ensure!(!flag, "flag was {flag}");
            bail!("always fails with code {}", 42);
        }
        assert_eq!(format!("{}", fails(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", fails(false).unwrap_err()), "always fails with code 42");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn new_retains_the_typed_value_through_context_frames() {
        let e = Error::new(Typed(7));
        assert_eq!(format!("{e}"), "typed error 7");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        // context frames wrap without losing the payload
        let wrapped = e.context("while serving");
        assert_eq!(format!("{wrapped}"), "while serving");
        assert_eq!(wrapped.downcast_ref::<Typed>(), Some(&Typed(7)));
        // message-only errors have nothing to downcast to
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
