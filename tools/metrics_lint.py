#!/usr/bin/env python3
"""Lint a Prometheus text-exposition file (version 0.0.4).

The serving pool exports its counters/gauges/stage histograms as
Prometheus text (`Pool::metrics_text`, rendered by
`rust/src/obs/metrics.rs`); the bench dumps one to
`reports/METRICS.prom` and CI runs this linter over it so a malformed
exposition — bad metric name, unescaped label value, non-cumulative
histogram, missing `# TYPE` — fails the build instead of failing the
first real scrape. Checks:

  - every line is a comment (`# HELP` / `# TYPE`), blank, or a sample
  - metric names match `[a-zA-Z_:][a-zA-Z0-9_:]*`, label names match
    `[a-zA-Z_][a-zA-Z0-9_]*`
  - label values are double-quoted with only `\\\\`, `\\"`, `\\n` escapes,
    and no label name repeats within one sample
  - sample values parse as floats (including +Inf/-Inf/NaN)
  - each family has exactly one `# TYPE` with a known kind, appearing
    before its samples; every sample belongs to a declared family
    (histogram samples may suffix `_bucket`/`_sum`/`_count`)
  - no duplicate (name, label-set) sample
  - histograms: per label-set the `le` buckets are cumulative
    (non-decreasing), end at `le="+Inf"`, and the `+Inf` count equals
    the family's `_count`; `_sum` and `_count` are present
  - label cardinality: no family may carry more than its cap of
    distinct label sets (`le` excluded, so histogram buckets don't
    count) — MAX_LABEL_SETS by default, with per-family overrides in
    FAMILY_CAPS for the per-arm attribution families whose legitimate
    cell count is kernel-kinds x joint arms. Anything past the cap
    means an unbounded label leaked into the exposition and would blow
    up a real scrape store.
  - the file is non-empty and ends with a newline

Usage: python3 tools/metrics_lint.py [FILE ...]
(default: reports/METRICS.prom). `--selftest` runs the linter against
built-in good/bad fixtures (CI runs it before linting real dumps).
Stdlib only — the CI image has no extra Python packages.
"""

import re
import sys

METRIC_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
KNOWN_KINDS = {"counter", "gauge", "histogram", "summary", "untyped"}
HIST_SUFFIXES = ("_bucket", "_sum", "_count")
# Distinct label sets allowed per family (le excluded). Cap math for
# the default: no plain family legitimately exceeds the stage fan-out
# (8 stages) or a small enum, so 64 leaves generous headroom while
# still catching an unbounded label (matrix id, request id) instantly.
MAX_LABEL_SETS = 64
# The per-arm attribution families carry {kind, format, knobs}: 3
# kernel kinds (spmv/sptrsv/symgs) x 48 joint (format, knob) arms =
# 144 legitimate cells, past the default cap by design. 192 = 4 x 48
# keeps one spare kind's headroom without tolerating a leaked label
# (which multiplies cardinality by the request count, not by 1.33x).
FAMILY_CAPS = {
    "spmv_arm_requests_total": 192,
    "spmv_arm_seconds_total": 192,
    "spmv_arm_energy_joules_total": 192,
    "spmv_arm_power_watts": 192,
    "spmv_arm_mflops_per_watt": 192,
}


class LintErrors:
    def __init__(self, path):
        self.path = path
        self.errors = []

    def add(self, lineno, msg):
        where = f"{self.path}:{lineno}" if lineno else self.path
        self.errors.append(f"{where}: {msg}")


def parse_labels(text, lineno, errs):
    """Parse `k="v",k2="v2"` (no surrounding braces) into a dict.

    Returns None when the syntax is broken beyond recovery.
    """
    labels = {}
    i, n = 0, len(text)
    while i < n:
        m = re.match(r"[a-zA-Z_][a-zA-Z0-9_]*", text[i:])
        if not m:
            errs.add(lineno, f"expected a label name at ...{text[i:]!r}")
            return None
        name = m.group(0)
        i += len(name)
        if i >= n or text[i] != "=":
            errs.add(lineno, f"label {name}: expected '=' after the name")
            return None
        i += 1
        if i >= n or text[i] != '"':
            errs.add(lineno, f"label {name}: value must be double-quoted")
            return None
        i += 1
        value = []
        closed = False
        while i < n:
            c = text[i]
            if c == "\\":
                if i + 1 >= n or text[i + 1] not in ('\\', '"', "n"):
                    errs.add(lineno, f"label {name}: bad escape at ...{text[i:]!r} "
                                     "(only \\\\, \\\", \\n are valid)")
                    return None
                value.append({"\\": "\\", '"': '"', "n": "\n"}[text[i + 1]])
                i += 2
            elif c == '"':
                closed = True
                i += 1
                break
            else:
                value.append(c)
                i += 1
        if not closed:
            errs.add(lineno, f"label {name}: unterminated value")
            return None
        if name in labels:
            errs.add(lineno, f"label {name} repeated within one sample")
            return None
        labels[name] = "".join(value)
        if i < n:
            if text[i] != ",":
                errs.add(lineno, f"expected ',' between labels, got {text[i]!r}")
                return None
            i += 1
            if i >= n:
                errs.add(lineno, "trailing ',' in label set")
                return None
    return labels


def parse_value(raw):
    try:
        return float(raw)
    except ValueError:
        return None


def lint_text(path, text):
    errs = LintErrors(path)
    if not text:
        errs.add(0, "empty exposition")
        return errs.errors
    if not text.endswith("\n"):
        errs.add(0, "exposition must end with a newline")

    types = {}  # family -> kind
    help_seen = set()
    samples = []  # (lineno, name, labels dict)
    seen_keys = set()

    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("#"):
            m = re.match(r"^# (HELP|TYPE) (\S+)(?: (.*))?$", line)
            if not m:
                # free-form comments are legal; only HELP/TYPE are parsed
                if re.match(r"^#\s*(HELP|TYPE)\b", line):
                    errs.add(lineno, f"malformed {line.split()[1]} line: {line!r}")
                continue
            kind_tag, name, rest = m.group(1), m.group(2), m.group(3) or ""
            if not METRIC_NAME.match(name):
                errs.add(lineno, f"invalid metric name in # {kind_tag}: {name!r}")
                continue
            if kind_tag == "HELP":
                if name in help_seen:
                    errs.add(lineno, f"duplicate # HELP for {name}")
                help_seen.add(name)
            else:
                if name in types:
                    errs.add(lineno, f"duplicate # TYPE for {name}")
                    continue
                if rest not in KNOWN_KINDS:
                    errs.add(lineno, f"unknown metric kind {rest!r} for {name}")
                    continue
                types[name] = rest
            continue

        # sample line: name[{labels}] value [timestamp]
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})?\s+(\S+)(?:\s+(-?\d+))?$", line)
        if not m:
            errs.add(lineno, f"unparseable sample line: {line!r}")
            continue
        name, label_body, raw_value = m.group(1), m.group(3), m.group(4)
        labels = {}
        if label_body is not None:
            labels = parse_labels(label_body, lineno, errs)
            if labels is None:
                continue
        if parse_value(raw_value) is None:
            errs.add(lineno, f"sample {name}: value {raw_value!r} is not a float")
            continue

        base = name
        suffix = ""
        for s in HIST_SUFFIXES:
            if name.endswith(s) and name[: -len(s)] in types:
                base, suffix = name[: -len(s)], s
                break
        if base not in types:
            errs.add(lineno, f"sample {name} has no preceding # TYPE")
            continue
        if suffix and types[base] != "histogram":
            # a plain family that merely ends in _count etc.
            base, suffix = name, ""
            if base not in types:
                errs.add(lineno, f"sample {name} has no preceding # TYPE")
                continue

        key = (name, tuple(sorted(labels.items())))
        if key in seen_keys:
            errs.add(lineno, f"duplicate sample {name}{dict(labels)}")
        seen_keys.add(key)
        samples.append((lineno, base, suffix, labels, float(raw_value)))

    # histogram shape checks, grouped by (family, labels-minus-le)
    hists = {}
    for lineno, base, suffix, labels, value in samples:
        if types.get(base) != "histogram":
            continue
        group_labels = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
        g = hists.setdefault((base, group_labels), {"buckets": [], "sum": None, "count": None})
        if suffix == "_bucket":
            if "le" not in labels:
                errs.add(lineno, f"{base}_bucket sample is missing the le label")
                continue
            g["buckets"].append((lineno, labels["le"], value))
        elif suffix == "_sum":
            g["sum"] = (lineno, value)
        elif suffix == "_count":
            g["count"] = (lineno, value)
        else:
            errs.add(lineno, f"histogram {base} has a bare sample (expected "
                             "_bucket/_sum/_count)")

    for (base, group_labels), g in sorted(hists.items()):
        tag = f"{base}{dict(group_labels) if group_labels else ''}"
        if not g["buckets"]:
            errs.add(0, f"histogram {tag}: no _bucket samples")
            continue
        prev = None
        for lineno, le, value in g["buckets"]:
            if le != "+Inf" and parse_value(le) is None:
                errs.add(lineno, f"histogram {tag}: le={le!r} is not a float or +Inf")
            if prev is not None and value < prev:
                errs.add(lineno, f"histogram {tag}: bucket counts must be "
                                 f"cumulative ({value} < {prev})")
            prev = value
        last_le = g["buckets"][-1][1]
        if last_le != "+Inf":
            errs.add(g["buckets"][-1][0],
                     f"histogram {tag}: buckets must end at le=\"+Inf\" (got {last_le!r})")
        if g["sum"] is None:
            errs.add(0, f"histogram {tag}: missing _sum sample")
        if g["count"] is None:
            errs.add(0, f"histogram {tag}: missing _count sample")
        elif last_le == "+Inf" and g["buckets"][-1][2] != g["count"][1]:
            errs.add(g["count"][0],
                     f"histogram {tag}: +Inf bucket ({g['buckets'][-1][2]}) != "
                     f"_count ({g['count'][1]})")

    # label-cardinality cap, per family (le excluded so a histogram's
    # bucket fan-out doesn't count against it)
    label_sets = {}
    for _, base, _, labels, _ in samples:
        key = tuple(sorted((k, v) for k, v in labels.items() if k != "le"))
        label_sets.setdefault(base, set()).add(key)
    for base, sets in sorted(label_sets.items()):
        cap = FAMILY_CAPS.get(base, MAX_LABEL_SETS)
        if len(sets) > cap:
            errs.add(0, f"family {base}: {len(sets)} label sets exceeds the "
                        f"cardinality cap of {cap} (an unbounded "
                        "label leaked into the exposition)")

    for name in sorted(help_seen - set(types)):
        errs.add(0, f"# HELP {name} has no matching # TYPE")

    return errs.errors


def selftest():
    """Lint built-in fixtures; returns 0 when every expectation holds."""
    def family(name, n_sets):
        lines = [
            f"# HELP {name} Requests per arm",
            f"# TYPE {name} counter",
        ]
        for i in range(n_sets):
            kind = ("spmv", "sptrsv", "symgs")[i % 3]
            lines.append(
                f'{name}{{kind="{kind}",format="csr",knobs="arm{i}"}} {i + 1}'
            )
        return "\n".join(lines) + "\n"

    arm_cap = FAMILY_CAPS["spmv_arm_requests_total"]
    cases = [
        # (name, text, substring expected among errors; None = clean)
        ("clean_at_default_cap", family("some_counter_total", MAX_LABEL_SETS), None),
        (
            "default_cardinality_overflow",
            family("some_counter_total", MAX_LABEL_SETS + 1),
            "cardinality cap",
        ),
        # the per-arm families legitimately exceed the default cap (3
        # kernel kinds x 48 joint arms) — their override admits the
        # full grid but still trips on a leaked unbounded label
        ("arm_family_at_override_cap", family("spmv_arm_requests_total", arm_cap), None),
        (
            "arm_family_cardinality_overflow",
            family("spmv_arm_requests_total", arm_cap + 1),
            "cardinality cap",
        ),
        (
            "duplicate_help",
            "# HELP a one\n# TYPE a counter\n# HELP a two\na 1\n",
            "duplicate # HELP",
        ),
        (
            "duplicate_sample",
            "# HELP a one\n# TYPE a counter\na 1\na 2\n",
            "duplicate sample",
        ),
        (
            "histogram_le_does_not_count",
            "# HELP h H\n# TYPE h histogram\n"
            + "".join(f'h_bucket{{le="{i}"}} {i + 1}\n' for i in range(MAX_LABEL_SETS + 1))
            + f'h_bucket{{le="+Inf"}} {MAX_LABEL_SETS + 1}\n'
            + f"h_sum 10\nh_count {MAX_LABEL_SETS + 1}\n",
            None,
        ),
        ("untyped_sample", "b 1\n", "no preceding # TYPE"),
    ]
    failed = 0
    for name, text, want in cases:
        errors = lint_text(f"<selftest:{name}>", text)
        if want is None:
            ok = not errors
            detail = "; ".join(errors)
        else:
            ok = any(want in e for e in errors)
            detail = f"expected an error containing {want!r}, got {errors}"
        print(f"{'ok' if ok else 'FAIL':4} selftest {name}")
        if not ok:
            print(f"     {detail}")
            failed += 1
    if failed:
        print(f"FAIL: {failed} selftest case(s)")
        return 1
    print(f"OK: {len(cases)} selftest cases held")
    return 0


def main(argv):
    if "--selftest" in argv[1:]:
        return selftest()
    paths = argv[1:] or ["reports/METRICS.prom"]
    failed = False
    for path in paths:
        try:
            with open(path, "r", encoding="utf-8") as f:
                text = f.read()
        except OSError as e:
            print(f"FAIL: cannot read {path}: {e}")
            failed = True
            continue
        errors = lint_text(path, text)
        if errors:
            failed = True
            print(f"FAIL: {path}: {len(errors)} problem(s)")
            for e in errors:
                print(f"  - {e}")
        else:
            n_samples = sum(
                1 for l in text.splitlines() if l.strip() and not l.startswith("#")
            )
            print(f"OK: {path}: {len(text.splitlines())} lines, "
                  f"{n_samples} samples, exposition is well-formed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
