#!/usr/bin/env python3
"""Gate benched metrics against the committed baseline.

The bench-smoke CI job runs `cargo bench --bench bench_e2e_serving --
--smoke`, which emits machine-readable tables as `reports/BENCH_*.json`
(`{"title", "header", "rows"}`, every cell a string). This script
compares the DETERMINISTIC metrics in those tables — accounting ledgers
like marshalled bytes per iteration and launches per request, never
wall-clock rates — against `reports/bench_baseline.json` and fails on
direction-aware regression beyond a small tolerance.

Usage:
    python3 tools/bench_gate.py                  # compare (CI gate)
    python3 tools/bench_gate.py --write-baseline # regenerate baseline
    python3 tools/bench_gate.py --reports DIR    # non-default location

Baseline keys are `<table>/<keycol>=<val>/.../<metric col>`. A key
present in the baseline but missing from the current reports is a
failure (the metric regressed away); a current metric absent from the
baseline is reported as new so a follow-up `--write-baseline` can adopt
it. Stdlib only — the CI image has no extra Python packages.
"""

import argparse
import json
import os
import sys

# Which tables/columns to gate. `keys` identify a row; `metrics` map a
# column to a direction ("lower" / "higher" is better) and a
# multiplicative tolerance. Only deterministic columns belong here:
# req/s and anything else wall-clock-derived would flake on a loaded
# CI runner.
CHECKS = [
    {
        "file": "BENCH_e2e_iterative_session.json",
        "table": "e2e_iterative_session",
        "keys": ["chain k", "path"],
        "metrics": {
            # marshalled-bytes-per-iteration ledger (PR 6 tentpole):
            # per-request rows pin the 8n/iter cost, session rows pin
            # the write+read-only cost
            "B/iter": {"direction": "lower", "tol": 1.05},
            # equal launches/request on both paths, exactly
            "launches/req": {"direction": "lower", "tol": 1.001},
            # per-request B/iter divided by session B/iter (the >= 10x
            # elision acceptance lives in the bench assert; the gate
            # pins the achieved ratio against creep)
            "bytes ratio": {"direction": "higher", "tol": 1.05},
        },
    },
    {
        "file": "BENCH_e2e_slo_breach.json",
        "table": "e2e_slo_breach",
        "keys": ["metric"],
        "metrics": {
            # the deterministic SLO breach episode (fixed request
            # schedule, request-counted windows): alert/recovery event
            # counts, the frozen flight-recorder window, the deadline
            # ledger, and the arm-attribution request total. All exact
            # counts, mode-independent — never wall-clock. The bench
            # asserts exact equality; the gate pins the floor so the
            # episode cannot silently stop alerting or stop recording.
            "value": {"direction": "higher", "tol": 1.0},
        },
    },
    {
        "file": "BENCH_e2e_zipf_scaleout.json",
        "table": "e2e_zipf_scaleout",
        "keys": ["metric"],
        "metrics": {
            # the scale-out control-plane ledger under the seeded Zipf
            # sweep: admitted requests, shed count (zero — no SLO is
            # attached), replication/unreplication decisions, live
            # replicas, and journaled control events. All exact counts
            # from the deterministic admission sequence — never
            # wall-clock (throughput lives in the ungated
            # e2e_zipf_throughput table). The bench asserts exact
            # equality; the gate pins the floor so the control plane
            # cannot silently stop replicating or journaling.
            "value": {"direction": "higher", "tol": 1.0},
        },
    },
    {
        "file": "BENCH_e2e_solver_chain.json",
        "table": "e2e_solver_chain",
        "keys": ["metric"],
        "metrics": {
            # the mixed-kind solver chain (direct SpMV/SpTRSV/SymGS
            # requests + a fixed-iteration SymGS-preconditioned CG
            # session): total requests/launches, the session-step tally,
            # the per-kind arm-attribution request counts, and the
            # solve_exec/session_step stage counts. All exact counts
            # from a fixed sequential native workload — never
            # wall-clock. The bench asserts exact equality; the gate
            # pins the floor so a kind can never silently stop being
            # served or attributed. The byte-ledger rows
            # (marshalled/elided) are emitted for the trajectory but
            # deliberately left out of the baseline.
            "value": {"direction": "higher", "tol": 1.0},
        },
    },
    {
        "file": "BENCH_e2e_stage_decomposition.json",
        "table": "e2e_stage_decomposition",
        "keys": ["stage"],
        "metrics": {
            # per-stage sample counts for the bench's fixed workload
            # (96 sequential products + one 32-step session) — fully
            # deterministic, mode-independent. A shortfall means a
            # stage stopped recording; the bench's own coverage assert
            # catches over-recording, so the gate pins the floor.
            "count": {"direction": "higher", "tol": 1.0},
            # populated only on the stage=all row ("-" elsewhere):
            # stage-decomposed time over end-to-end service time, times
            # 100 — exactly 100 by construction (the shard derives both
            # from the same boundary instants).
            "coverage %": {"direction": "higher", "tol": 1.0},
        },
    },
]


def load_table(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    header = doc["header"]
    return [dict(zip(header, row)) for row in doc["rows"]]


def to_float(cell):
    try:
        return float(cell)
    except (TypeError, ValueError):
        return None


def collect_metrics(reports_dir):
    """Extract `{key: value}` for every configured metric present."""
    metrics = {}
    missing_files = []
    for check in CHECKS:
        path = os.path.join(reports_dir, check["file"])
        if not os.path.exists(path):
            missing_files.append(check["file"])
            continue
        for row in load_table(path):
            row_key = "/".join(f"{k}={row[k]}" for k in check["keys"] if k in row)
            for col, _ in check["metrics"].items():
                val = to_float(row.get(col))
                if val is not None:
                    metrics[f"{check['table']}/{row_key}/{col}"] = val
    return metrics, missing_files


def metric_spec(key):
    for check in CHECKS:
        if key.startswith(check["table"] + "/"):
            for col, spec in check["metrics"].items():
                if key.endswith("/" + col):
                    return spec
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reports", default="reports", help="reports directory")
    ap.add_argument(
        "--baseline",
        default=os.path.join("reports", "bench_baseline.json"),
        help="committed baseline path",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="regenerate the baseline from the current reports",
    )
    args = ap.parse_args()

    current, missing_files = collect_metrics(args.reports)

    if args.write_baseline:
        if missing_files:
            print(f"FAIL: cannot write a baseline with reports missing: {missing_files}")
            return 1
        doc = {
            "_comment": "Deterministic bench-smoke metrics gated by tools/bench_gate.py; "
            "regenerate with `python3 tools/bench_gate.py --write-baseline` "
            "after an intentional perf change.",
            "metrics": dict(sorted(current.items())),
        }
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(current)} metrics to {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"FAIL: no committed baseline at {args.baseline}")
        print("bootstrap one with: python3 tools/bench_gate.py --write-baseline")
        return 1
    with open(args.baseline, "r", encoding="utf-8") as f:
        baseline = json.load(f)["metrics"]

    if missing_files:
        print(f"FAIL: expected bench reports missing from {args.reports}: {missing_files}")
        return 1

    failures = []
    for key, base in sorted(baseline.items()):
        spec = metric_spec(key)
        if spec is None:
            # baseline entry no longer configured — stale, not fatal
            print(f"WARN: baseline metric not configured in CHECKS, skipping: {key}")
            continue
        cur = current.get(key)
        if cur is None:
            failures.append(f"{key}: present in baseline ({base}) but missing from reports")
            continue
        tol = spec["tol"]
        if spec["direction"] == "lower":
            ok, bound = cur <= base * tol, base * tol
            cmp = f"{cur} > allowed {bound:.4g}"
        else:
            ok, bound = cur >= base / tol, base / tol
            cmp = f"{cur} < required {bound:.4g}"
        status = "ok" if ok else "REGRESSED"
        print(f"{status:9} {key}: baseline {base}, current {cur}")
        if not ok:
            failures.append(f"{key}: {cmp} (baseline {base})")

    new = sorted(set(current) - set(baseline))
    for key in new:
        print(f"NEW       {key}: {current[key]} (not in baseline; "
              "adopt with --write-baseline)")

    if failures:
        print(f"\nFAIL: {len(failures)} metric(s) regressed past the committed baseline:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print(f"\nOK: {len(baseline)} baseline metric(s) held (tolerances per tools/bench_gate.py)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
