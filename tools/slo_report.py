#!/usr/bin/env python3
"""Summarize the serving pool's SLO posture and per-arm attribution.

Reads the two observability artifacts the bench-smoke job dumps —
`reports/METRICS.prom` (Prometheus text exposition, DESIGN.md §10.3)
and `reports/EVENTS.json` (the control-plane journal) — and prints a
human-readable report:

  - SLO: status, targets, evaluations/alerts/recoveries, burn rates,
    the deadline ledger, and the flight-recorder capture count
    (`spmv_slo_*` / `spmv_flight_records`); says so when the dump was
    produced without an SLO configured
  - per-arm attribution: one row per (kind, format, knobs) joint arm
    from `spmv_arm_*` — the kernel-kind label keeps SpMV, SpTRSV, and
    SymGS windows apart — sorted by request count: where the time and
    the modeled energy actually went (DESIGN.md §11)
  - scale-out control plane: replication/reroute/shed counters, live
    replicas, and per-shard queue depths (`spmv_replicas`,
    `spmv_sheds_total`, `spmv_queue_depth`; DESIGN.md §12)
  - journal: counts per event kind plus the full slo_alert /
    slo_recovered / arm_shift lines and the replicate / unreplicate /
    reroute / shed control-plane timeline, in sequence order

Exit status: 0 on a well-formed report (even with zero SLO families),
nonzero when either input is missing or malformed — CI runs this after
`metrics_lint.py`, so a failure here means the report schema drifted
from the exposition, not a cosmetic problem.

Usage: python3 tools/slo_report.py [--metrics FILE] [--events FILE]
Stdlib only — the CI image has no extra Python packages.
"""

import argparse
import json
import re
import sys

SAMPLE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)$")
LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

SLO_STATUS = {0: "ok", 1: "warning", 2: "breach"}
SLO_EVENT_KINDS = ("slo_alert", "slo_recovered", "arm_shift")
SCALEOUT_EVENT_KINDS = ("replicate", "unreplicate", "reroute", "shed")


def parse_metrics(path):
    """Parse a Prometheus text exposition into [(name, labels, value)].

    Raises ValueError on an unparseable sample line — the lint catches
    structural problems first, so anything malformed here is fatal.
    """
    samples = []
    with open(path, "r", encoding="utf-8") as f:
        for lineno, line in enumerate(f, start=1):
            line = line.rstrip("\n")
            if not line.strip() or line.startswith("#"):
                continue
            m = SAMPLE.match(line)
            if not m:
                raise ValueError(f"{path}:{lineno}: unparseable sample: {line!r}")
            name, label_body, raw = m.groups()
            try:
                value = float(raw)
            except ValueError:
                raise ValueError(f"{path}:{lineno}: non-float value: {line!r}")
            labels = dict(LABEL.findall(label_body)) if label_body else {}
            samples.append((name, labels, value))
    if not samples:
        raise ValueError(f"{path}: no samples in the exposition")
    return samples


def scalar(samples, name):
    """The value of an unlabeled family, or None when absent."""
    for n, labels, value in samples:
        if n == name and not labels:
            return value
    return None


def fmt(value, pattern="{:.6g}"):
    return "-" if value is None else pattern.format(value)


def report_slo(samples):
    status = scalar(samples, "spmv_slo_status")
    print("== SLO ==")
    if status is None:
        print("no spmv_slo_* families: the pool ran without an SLO configured")
        return
    name = SLO_STATUS.get(int(status), f"unknown({status:.0f})")
    print(f"status:           {name}")
    print(f"p99 target:       {fmt(scalar(samples, 'spmv_slo_p99_target_seconds'))} s")
    print(f"miss budget:      {fmt(scalar(samples, 'spmv_slo_miss_budget_ratio'))}")
    print(f"evaluations:      {fmt(scalar(samples, 'spmv_slo_evals_total'), '{:.0f}')}")
    print(f"alerts:           {fmt(scalar(samples, 'spmv_slo_alerts_total'), '{:.0f}')}")
    print(f"recoveries:       {fmt(scalar(samples, 'spmv_slo_recoveries_total'), '{:.0f}')}")
    print(f"fast burn rate:   {fmt(scalar(samples, 'spmv_slo_fast_burn_ratio'))}")
    print(f"slow burn rate:   {fmt(scalar(samples, 'spmv_slo_slow_burn_ratio'))}")
    print(f"window p99:       {fmt(scalar(samples, 'spmv_slo_window_p99_seconds'))} s")
    tagged = scalar(samples, "spmv_deadline_tagged_total")
    missed = scalar(samples, "spmv_deadline_misses_total")
    print(f"deadline ledger:  {fmt(missed, '{:.0f}')}/{fmt(tagged, '{:.0f}')} "
          "tagged requests missed")
    print(f"flight capture:   {fmt(scalar(samples, 'spmv_flight_records'), '{:.0f}')} "
          "trace records frozen by the last breach")


def report_arms(samples):
    arms = {}
    for n, labels, value in samples:
        if not n.startswith("spmv_arm_") or "format" not in labels:
            continue
        # kind entered the arm label set with the solve kernel classes;
        # default it for older dumps so pre-kind expositions still parse
        key = (
            labels.get("kind", "spmv"),
            labels.get("format", "?"),
            labels.get("knobs", "?"),
        )
        arms.setdefault(key, {})[n] = value
    gen = scalar(samples, "spmv_arm_generation")
    print("\n== per-arm attribution ==")
    if not arms:
        print("no labeled spmv_arm_* samples: no requests were attributed")
        return
    print(f"policy generation: {fmt(gen, '{:.0f}')}, {len(arms)} arm(s) with traffic")
    header = ("arm", "requests", "exec s", "energy J", "avg W", "MFLOPS/W")
    rows = [header]
    order = sorted(
        arms.items(),
        key=lambda kv: (-kv[1].get("spmv_arm_requests_total", 0), kv[0]),
    )
    for (kind, fmt_name, knobs), vals in order:
        rows.append((
            f"{kind}/{fmt_name}@{knobs}",
            fmt(vals.get("spmv_arm_requests_total"), "{:.0f}"),
            fmt(vals.get("spmv_arm_seconds_total")),
            fmt(vals.get("spmv_arm_energy_joules_total")),
            fmt(vals.get("spmv_arm_power_watts")),
            fmt(vals.get("spmv_arm_mflops_per_watt")),
        ))
    widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())


def report_scaleout(samples):
    """Scale-out control plane posture (DESIGN.md §12)."""
    repl = scalar(samples, "spmv_replications_total")
    print("\n== scale-out control plane ==")
    if repl is None:
        print("no spmv_replications_total: exposition predates the scale-out "
              "control plane")
        return
    sheds = {l.get("reason", "?"): v
             for n, l, v in samples if n == "spmv_sheds_total"}
    depths = sorted((int(l.get("shard", -1)), v)
                    for n, l, v in samples if n == "spmv_queue_depth")
    print(f"replications:     {fmt(repl, '{:.0f}')}")
    print(f"unreplications:   "
          f"{fmt(scalar(samples, 'spmv_unreplications_total'), '{:.0f}')}")
    print(f"live replicas:    {fmt(scalar(samples, 'spmv_replicas'), '{:.0f}')}")
    print(f"reroutes:         {fmt(scalar(samples, 'spmv_reroutes_total'), '{:.0f}')}")
    by_reason = ", ".join(f"{k} {v:.0f}" for k, v in sorted(sheds.items())) or "-"
    total = sum(sheds.values()) if sheds else None
    print(f"sheds:            {fmt(total, '{:.0f}')} ({by_reason})")
    if depths:
        print("queue depths:     "
              + ", ".join(f"shard {s}: {v:.0f}" for s, v in depths))


def report_events(path):
    with open(path, "r", encoding="utf-8") as f:
        events = json.load(f)
    if not isinstance(events, list):
        raise ValueError(f"{path}: expected a JSON array of events")
    counts = {}
    for e in events:
        if not isinstance(e, dict) or "kind" not in e or "seq" not in e:
            raise ValueError(f"{path}: malformed event: {e!r}")
        counts[e["kind"]] = counts.get(e["kind"], 0) + 1
    print("\n== control-plane journal ==")
    if not events:
        print("journal is empty")
        return
    print(f"{len(events)} event(s): "
          + ", ".join(f"{k} x{v}" for k, v in sorted(counts.items())))
    slo_events = [e for e in events if e["kind"] in SLO_EVENT_KINDS]
    if slo_events:
        print("SLO / attribution events, in sequence order:")
        for e in slo_events:
            print(f"  #{e['seq']:<4} {e.get('detail', e['kind'])}")
    else:
        print("no slo_alert/slo_recovered/arm_shift events journaled")
    scaleout_events = [e for e in events if e["kind"] in SCALEOUT_EVENT_KINDS]
    if scaleout_events:
        print("scale-out control-plane timeline, in sequence order:")
        for e in scaleout_events:
            print(f"  #{e['seq']:<4} {e.get('detail', e['kind'])}")
    else:
        print("no replicate/unreplicate/reroute/shed events journaled")


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", default="reports/METRICS.prom")
    ap.add_argument("--events", default="reports/EVENTS.json")
    args = ap.parse_args(argv[1:])
    try:
        samples = parse_metrics(args.metrics)
        report_slo(samples)
        report_arms(samples)
        report_scaleout(samples)
        report_events(args.events)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
