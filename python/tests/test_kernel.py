"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Fixed-seed smoke tests for every (format, x_placement) pair, plus
hypothesis sweeps over shapes/grids/padding density (Deliverable (c):
hypothesis sweeps the Pallas kernels' shapes and asserts allclose vs ref).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bell, csr, ell, ref, sell
from compile.kernels.common import Variant
from .conftest import make_bell, make_coo, make_ell, make_sell, make_x

SET = settings(max_examples=15, deadline=None)


def run_ell(v, data, cols, x):
    fn, _ = ell.build(v)
    return np.asarray(jax.jit(fn)(data, cols, x)[0])


# ---------------------------------------------------------------- ELL ----

@pytest.mark.parametrize("place", ["resident", "gather", "streamed"])
def test_ell_placements(rng, place):
    n, m, w = 64, 64, 8
    data, cols = make_ell(rng, n, m, w)
    x = make_x(rng, m)
    want = np.asarray(ref.ell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    extra = (("xseg", m // 4),) if place == "streamed" else ()
    v = Variant("ell", n, m, w, 16, 4, place, extra=extra)
    got = run_ell(v, data, cols, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@SET
@given(
    lg_n=st.integers(4, 7),          # n in 16..128
    w_mul=st.integers(1, 4),         # w = 4*w_mul
    br_div=st.sampled_from([1, 2, 4]),
    cw_div=st.sampled_from([1, 2]),
    pad=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_ell_hypothesis(lg_n, w_mul, br_div, cw_div, pad, seed):
    n = 2 ** lg_n
    m = n
    w = 4 * w_mul
    br = max(n // br_div // 4, 1)
    # ensure divisibility
    while n % br:
        br -= 1
    cw = w // cw_div if w % cw_div == 0 else w
    rng = np.random.default_rng(seed)
    data, cols = make_ell(rng, n, m, w, pad_frac=pad)
    x = make_x(rng, m)
    want = np.asarray(ref.ell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    v = Variant("ell", n, m, w, br, cw, "resident")
    got = run_ell(v, data, cols, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ell_all_padding(rng):
    """A fully padded (empty) matrix must produce exactly zero."""
    n = m = 32
    w = 4
    data = np.zeros((n, w), np.float32)
    cols = np.zeros((n, w), np.int32)
    x = make_x(rng, m)
    v = Variant("ell", n, m, w, 8, 4, "resident")
    got = run_ell(v, data, cols, x)
    np.testing.assert_array_equal(got, np.zeros(n, np.float32))


def test_ell_grid_indivisible_rejected():
    with pytest.raises(AssertionError):
        ell.build(Variant("ell", 100, 100, 8, 33, 4, "resident"))


# --------------------------------------------------------------- BELL ----

@pytest.mark.parametrize("place", ["resident", "gather"])
def test_bell_placements(rng, place):
    nb, kb, bh, bw, m = 8, 4, 8, 8, 64
    data, bcols = make_bell(rng, nb, kb, bh, bw, m)
    x = make_x(rng, m)
    want = np.asarray(ref.bell_spmv(jnp.array(data), jnp.array(bcols), jnp.array(x)))
    v = Variant("bell", nb * bh, m, kb, 4, 2, place, extra=(("bh", bh), ("bw", bw)))
    fn, _ = bell.build(v)
    got = np.asarray(jax.jit(fn)(data, bcols, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(
    nb=st.sampled_from([4, 8, 16]),
    kb=st.sampled_from([2, 4]),
    blk=st.sampled_from([(4, 4), (8, 8)]),
    pad=st.floats(0.0, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
def test_bell_hypothesis(nb, kb, blk, pad, seed):
    bh, bw = blk
    m = max(nb * bh, kb * bw * 2)
    m = ((m + bw - 1) // bw) * bw
    rng = np.random.default_rng(seed)
    data, bcols = make_bell(rng, nb, kb, bh, bw, m, pad_frac=pad)
    x = make_x(rng, m)
    want = np.asarray(ref.bell_spmv(jnp.array(data), jnp.array(bcols), jnp.array(x)))
    v = Variant("bell", nb * bh, m, kb, nb // 2 or 1, kb // 2 or 1, "resident",
                extra=(("bh", bh), ("bw", bw)))
    fn, _ = bell.build(v)
    got = np.asarray(jax.jit(fn)(data, bcols, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_bell_unsupported_placement():
    with pytest.raises(ValueError):
        bell.build(Variant("bell", 64, 64, 4, 4, 2, "streamed",
                           extra=(("bh", 8), ("bw", 8))))


# --------------------------------------------------------------- SELL ----

@pytest.mark.parametrize("place", ["resident", "gather"])
def test_sell_placements(rng, place):
    ns, h, w, m = 8, 8, 8, 64
    data, cols = make_sell(rng, ns, h, w, m)
    x = make_x(rng, m)
    want = np.asarray(ref.sell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    v = Variant("sell", ns * h, m, w, 2, 4, place, extra=(("h", h),))
    fn, _ = sell.build(v)
    got = np.asarray(jax.jit(fn)(data, cols, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(
    ns=st.sampled_from([2, 4, 8]),
    h=st.sampled_from([4, 8]),
    w=st.sampled_from([4, 8]),
    pad=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_sell_hypothesis(ns, h, w, pad, seed):
    m = ns * h
    rng = np.random.default_rng(seed)
    data, cols = make_sell(rng, ns, h, w, m, pad_frac=pad)
    x = make_x(rng, m)
    want = np.asarray(ref.sell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    v = Variant("sell", ns * h, m, w, ns // 2 or 1, w // 2 or 1, "resident",
                extra=(("h", h),))
    fn, _ = sell.build(v)
    got = np.asarray(jax.jit(fn)(data, cols, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- CSR ----

@pytest.mark.parametrize("place", ["resident", "gather"])
def test_csr_placements(rng, place):
    n, m, nnz = 64, 64, 256
    vals, rows, cols = make_coo(rng, n, m, nnz)
    x = make_x(rng, m)
    want = np.asarray(ref.coo_spmv(jnp.array(vals), jnp.array(rows),
                                   jnp.array(cols), jnp.array(x), n))
    v = Variant("csr", n, m, nnz, 0, 64, place)
    fn, _ = csr.build(v)
    got = np.asarray(jax.jit(fn)(vals, rows, cols, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(
    n=st.sampled_from([16, 64, 128]),
    nnz_mul=st.integers(1, 8),
    chunk_div=st.sampled_from([1, 2, 4]),
    pad=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_csr_hypothesis(n, nnz_mul, chunk_div, pad, seed):
    m = n
    nnz = 32 * nnz_mul
    chunk = nnz // chunk_div
    rng = np.random.default_rng(seed)
    vals, rows, cols = make_coo(rng, n, m, nnz, pad_frac=pad)
    x = make_x(rng, m)
    want = np.asarray(ref.coo_spmv(jnp.array(vals), jnp.array(rows),
                                   jnp.array(cols), jnp.array(x), n))
    v = Variant("csr", n, m, nnz, 0, chunk, "resident")
    fn, _ = csr.build(v)
    got = np.asarray(jax.jit(fn)(vals, rows, cols, x)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_csr_duplicate_row_entries_accumulate(rng):
    """Multiple nnz in the same (row, col) must sum, not overwrite."""
    n = m = 8
    vals = np.array([1.0, 2.0, 3.0, 0.0], np.float32)
    rows = np.array([3, 3, 3, 0], np.int32)
    cols = np.array([1, 1, 2, 0], np.int32)
    x = np.arange(1, m + 1, dtype=np.float32)
    v = Variant("csr", n, m, 4, 0, 2, "resident")
    fn, _ = csr.build(v)
    got = np.asarray(jax.jit(fn)(vals, rows, cols, x)[0])
    want = np.zeros(n, np.float32)
    want[3] = 1.0 * x[1] + 2.0 * x[1] + 3.0 * x[2]
    np.testing.assert_allclose(got, want, rtol=1e-6)
