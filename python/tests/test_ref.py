"""The oracles' oracle: every ref.py format oracle vs a dense matmul.

Each test densifies a randomly generated sparse operand and checks the
format-specific oracle against ``A_dense @ x``.
"""

import numpy as np
import jax.numpy as jnp

from compile.kernels import ref
from .conftest import make_bell, make_coo, make_ell, make_sell, make_x


def ell_to_dense(data, cols, m):
    n, w = data.shape
    a = np.zeros((n, m), np.float32)
    for i in range(n):
        for j in range(w):
            a[i, cols[i, j]] += data[i, j]
    return a


def bell_to_dense(data, bcols, m):
    nb, kb, bh, bw = data.shape
    a = np.zeros((nb * bh, m), np.float32)
    for ib in range(nb):
        for k in range(kb):
            c0 = bcols[ib, k] * bw
            a[ib * bh:(ib + 1) * bh, c0:c0 + bw] += data[ib, k]
    return a


def sell_to_dense(data, cols, m):
    ns, h, w = data.shape
    a = np.zeros((ns * h, m), np.float32)
    for s in range(ns):
        for i in range(h):
            for j in range(w):
                a[s * h + i, cols[s, i, j]] += data[s, i, j]
    return a


def coo_to_dense(vals, rows, cols, n, m):
    a = np.zeros((n, m), np.float32)
    for v, r, c in zip(vals, rows, cols):
        a[r, c] += v
    return a


def test_ell_ref_matches_dense(rng):
    n, m, w = 32, 48, 6
    data, cols = make_ell(rng, n, m, w)
    x = make_x(rng, m)
    want = ell_to_dense(data, cols, m) @ x
    got = np.asarray(ref.ell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bell_ref_matches_dense(rng):
    nb, kb, bh, bw, m = 6, 3, 4, 4, 32
    data, bcols = make_bell(rng, nb, kb, bh, bw, m)
    x = make_x(rng, m)
    want = bell_to_dense(data, bcols, m) @ x
    got = np.asarray(ref.bell_spmv(jnp.array(data), jnp.array(bcols), jnp.array(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sell_ref_matches_dense(rng):
    ns, h, w, m = 5, 4, 7, 40
    data, cols = make_sell(rng, ns, h, w, m)
    x = make_x(rng, m)
    want = sell_to_dense(data, cols, m) @ x
    got = np.asarray(ref.sell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_coo_ref_matches_dense(rng):
    n, m, nnz = 24, 36, 120
    vals, rows, cols = make_coo(rng, n, m, nnz)
    x = make_x(rng, m)
    want = coo_to_dense(vals, rows, cols, n, m) @ x
    got = np.asarray(ref.coo_spmv(jnp.array(vals), jnp.array(rows),
                                  jnp.array(cols), jnp.array(x), n))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_ref_identity(rng):
    a = rng.standard_normal((8, 8)).astype(np.float32)
    x = make_x(rng, 8)
    np.testing.assert_allclose(
        np.asarray(ref.dense_spmv(jnp.array(a), jnp.array(x))), a @ x, rtol=1e-5)


def test_ell_ref_zero_matrix():
    data = np.zeros((4, 3), np.float32)
    cols = np.zeros((4, 3), np.int32)
    x = np.ones(4, np.float32)
    got = np.asarray(ref.ell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    np.testing.assert_array_equal(got, np.zeros(4, np.float32))
