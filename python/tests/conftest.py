"""Shared test fixtures: random sparse operands per format.

All generators zero out a random subset of entries and reset their indices
to 0 — the padding convention shared with the Rust substrate — so every
test also exercises padding correctness.
"""

import numpy as np
import pytest


def make_ell(rng, n, m, w, pad_frac=0.3):
    data = rng.standard_normal((n, w)).astype(np.float32)
    cols = rng.integers(0, m, (n, w)).astype(np.int32)
    mask = rng.random((n, w)) < pad_frac
    data[mask] = 0.0
    cols[mask] = 0
    return data, cols


def make_bell(rng, nb, kb, bh, bw, m, pad_frac=0.3):
    data = rng.standard_normal((nb, kb, bh, bw)).astype(np.float32)
    bcols = rng.integers(0, m // bw, (nb, kb)).astype(np.int32)
    mask = rng.random((nb, kb)) < pad_frac
    data[mask] = 0.0
    bcols[mask] = 0
    return data, bcols


def make_sell(rng, ns, h, w, m, pad_frac=0.4):
    data = rng.standard_normal((ns, h, w)).astype(np.float32)
    cols = rng.integers(0, m, (ns, h, w)).astype(np.int32)
    mask = rng.random((ns, h, w)) < pad_frac
    data[mask] = 0.0
    cols[mask] = 0
    return data, cols


def make_coo(rng, n, m, nnz, pad_frac=0.2):
    vals = rng.standard_normal(nnz).astype(np.float32)
    rows = np.sort(rng.integers(0, n, nnz)).astype(np.int32)
    cols = rng.integers(0, m, nnz).astype(np.int32)
    mask = rng.random(nnz) < pad_frac
    vals[mask] = 0.0
    rows[mask] = 0
    cols[mask] = 0
    return vals, rows, cols


def make_x(rng, m):
    return rng.standard_normal(m).astype(np.float32)


@pytest.fixture
def rng():
    return np.random.default_rng(0xA5BD)
