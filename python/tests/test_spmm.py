"""SpMM (multi-vector) lowerings vs the per-vector oracles.

The SpMM contract the Rust runtime relies on: for a batch bucket of k
vectors, row i of the kernel's (k, rows) output equals the SpMV of input
vector i — including zero-padded batch rows (a coalesced batch smaller
than the bucket pads with zero vectors and must get exact zeros back).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import bell, csr, ell, ref, sell
from compile.kernels.common import Variant
from .conftest import make_bell, make_coo, make_ell, make_sell, make_x


def make_xs(rng, k, m):
    return rng.standard_normal((k, m)).astype(np.float32)


# ---------------------------------------------------------------- ELL ----

@pytest.mark.parametrize("place", ["resident", "gather"])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_ell_spmm_matches_per_vector(rng, place, k):
    n, m, w = 64, 64, 8
    data, cols = make_ell(rng, n, m, w)
    xs = make_xs(rng, k, m)
    v = Variant("ell", n, m, w, 16, 4, place, ncols=k)
    fn, _ = ell.build(v)
    got = np.asarray(jax.jit(fn)(data, cols, xs)[0])
    assert got.shape == (k, n)
    for i in range(k):
        want = np.asarray(ref.ell_spmv(jnp.array(data), jnp.array(cols),
                                       jnp.array(xs[i])))
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-5)


def test_ell_spmm_zero_padded_batch_rows_give_exact_zero(rng):
    n, m, w, k = 32, 32, 4, 4
    data, cols = make_ell(rng, n, m, w)
    xs = make_xs(rng, k, m)
    xs[2:] = 0.0  # a 2-request batch padded up to the bucket of 4
    v = Variant("ell", n, m, w, 8, 4, "resident", ncols=k)
    fn, _ = ell.build(v)
    got = np.asarray(jax.jit(fn)(data, cols, xs)[0])
    np.testing.assert_array_equal(got[2:], np.zeros((2, n), np.float32))


def test_ell_spmm_rejects_streamed():
    with pytest.raises(ValueError):
        ell.build(Variant("ell", 32, 32, 4, 8, 4, "streamed", ncols=4,
                          extra=(("xseg", 8),)))


# ---------------------------------------------------------------- CSR ----

@pytest.mark.parametrize("place", ["resident", "gather"])
@pytest.mark.parametrize("k", [2, 8])
def test_csr_spmm_matches_per_vector(rng, place, k):
    n = m = 48
    vals, rows, cols = make_coo(rng, n, m, nnz=256)
    xs = make_xs(rng, k, m)
    v = Variant("csr", n, m, 256, 0, 64, place, ncols=k)
    fn, _ = csr.build(v)
    got = np.asarray(jax.jit(fn)(vals, rows, cols, xs)[0])
    assert got.shape == (k, n)
    for i in range(k):
        want = np.asarray(ref.coo_spmv(jnp.array(vals), jnp.array(rows),
                                       jnp.array(cols), jnp.array(xs[i]), n))
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- SELL ----

@pytest.mark.parametrize("place", ["resident", "gather"])
def test_sell_spmm_matches_per_vector(rng, place):
    ns, h, w, m, k = 8, 8, 4, 64, 4
    data, cols = make_sell(rng, ns, h, w, m)
    xs = make_xs(rng, k, m)
    v = Variant("sell", ns * h, m, w, 4, 4, place, ncols=k, extra=(("h", h),))
    fn, _ = sell.build(v)
    got = np.asarray(jax.jit(fn)(data, cols, xs)[0])
    assert got.shape == (k, ns * h)
    for i in range(k):
        want = np.asarray(ref.sell_spmv(jnp.array(data), jnp.array(cols),
                                        jnp.array(xs[i])))
        np.testing.assert_allclose(got[i], want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- BELL ----

@pytest.mark.parametrize("place", ["resident", "gather"])
def test_bell_spmm_matches_per_vector(rng, place):
    nb, kb, bh, bw, m, k = 8, 4, 8, 8, 64, 4
    data, bcols = make_bell(rng, nb, kb, bh, bw, m)
    xs = make_xs(rng, k, m)
    v = Variant("bell", nb * bh, m, kb, 4, 2, place, ncols=k,
                extra=(("bh", bh), ("bw", bw)))
    fn, _ = bell.build(v)
    got = np.asarray(jax.jit(fn)(data, bcols, xs)[0])
    assert got.shape == (k, nb * bh)
    for i in range(k):
        want = np.asarray(ref.bell_spmv(jnp.array(data), jnp.array(bcols),
                                        jnp.array(xs[i])))
        np.testing.assert_allclose(got[i], want, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------- inventory / aot ----

def test_spmm_variant_names_are_unique_and_tagged():
    vs = model.spmm_variants()
    names = [v.name for v in vs]
    assert len(names) == len(set(names))
    assert all(v.ncols > 1 for v in vs)
    assert all(f"_x{v.ncols}" in v.name for v in vs)
    assert {v.fmt for v in vs} == {"csr", "ell", "bell", "sell"}


def test_spmm_inventory_is_knob_swept():
    """Every format's SpMM rows carry >= 2 distinct knob triples (the
    joint runtime re-selects SpMM artifacts on knob hot-swaps), and no
    variant uses the streamed placement (no SpMM lowering exists)."""
    vs = model.spmm_variants()
    assert all(v.x_placement in ("resident", "gather") for v in vs)
    for fmt in ("csr", "ell", "bell", "sell"):
        knobs = {(v.block_rows, v.chunk_width, v.x_placement)
                 for v in vs if v.fmt == fmt}
        assert len(knobs) >= 2, f"{fmt}: SpMM inventory not knob-swept: {knobs}"
    # ELL sweeps the full block_rows x chunk_width x placement grid
    ell = {(v.block_rows, v.chunk_width, v.x_placement)
           for v in vs if v.fmt == "ell" and v.rows == 1024}
    assert len(ell) == 8


def test_quick_spmm_inventory_has_a_knob_alternative():
    vs = model.spmm_variants(quick=True)
    ell_places = {v.x_placement for v in vs if v.fmt == "ell"}
    assert ell_places == {"resident", "gather"}, \
        "quick CI set must exercise the knob-break path"


def test_all_spmm_variants_build():
    for v in model.spmm_variants():
        fn, example = model.build_spmm(v)
        assert callable(fn)
        # X is the LAST input: (ncols, cols), one vector per row
        assert example[-1].shape == (v.ncols, v.cols)


def test_extra_str_carries_the_batch_bucket():
    v = Variant("ell", 256, 256, 16, 64, 8, "resident", ncols=8)
    assert aot.extra_str(v) == "nc=8"
    v2 = Variant("sell", 256, 256, 16, 8, 8, "resident", ncols=4,
                 extra=(("h", 8),))
    assert aot.extra_str(v2) == "h=8;nc=4"
    v3 = Variant("ell", 256, 256, 16, 64, 8, "resident")
    assert aot.extra_str(v3) == "-"


def test_spmm_hlo_text_lowers():
    v = Variant("ell", 64, 64, 8, 16, 4, "resident", ncols=4)
    fn, example = model.build_spmm(v)
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    assert "HloModule" in text


def test_spmv_variant_names_unchanged_at_ncols_1():
    v = Variant("ell", 256, 256, 16, 64, 8, "resident")
    assert v.name == "ell_r256_c256_w16_b64_k8_resident"
    with pytest.raises(ValueError):
        Variant("ell", 256, 256, 16, 64, 8, "resident", ncols=0)
