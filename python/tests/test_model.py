"""L2 graph tests: variant inventory sanity + composed graphs."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from compile.kernels.common import Variant
from .conftest import make_ell, make_x


def test_default_variants_unique_names():
    vs = model.default_variants()
    names = [v.name for v in vs]
    assert len(names) == len(set(names))
    assert len(vs) >= 30  # a real sweep, not a stub


def test_default_variants_cover_all_formats():
    fmts = {v.fmt for v in model.default_variants()}
    assert fmts == {"csr", "ell", "bell", "sell"}


def test_quick_subset_is_subsetlike():
    quick = model.default_variants(quick=True)
    assert 0 < len(quick) <= 8
    assert {v.fmt for v in quick} == {"csr", "ell", "bell", "sell"}


def test_all_default_variants_build():
    """Every advertised variant must construct (shapes divide grids)."""
    for v in model.default_variants():
        fn, example = model.build_spmv(v)
        assert callable(fn)
        assert example[-1].shape == (v.cols,)


def test_power_step_normalizes(rng):
    v = model.power_step_variants()[0]
    fn, _ = model.build_power_step(v)
    data, cols = make_ell(rng, v.rows, v.cols, v.width)
    x = make_x(rng, v.cols)
    (y,) = jax.jit(fn)(data, cols, x)
    y = np.asarray(y)
    np.testing.assert_allclose(np.linalg.norm(y), 1.0, rtol=1e-4)
    # direction matches the raw spmv
    raw = np.asarray(ref.ell_spmv(jnp.array(data), jnp.array(cols), jnp.array(x)))
    np.testing.assert_allclose(y, raw / np.linalg.norm(raw), rtol=1e-4, atol=1e-5)


def test_variant_name_roundtrips_knobs():
    v = Variant("ell", 256, 256, 16, 64, 8, "streamed", extra=(("xseg", 64),))
    assert v.name == "ell_r256_c256_w16_b64_k8_streamed_xseg64"


def test_variant_rejects_bad_format():
    import pytest
    with pytest.raises(ValueError):
        Variant("hyb", 256, 256, 16, 64, 8, "resident")
    with pytest.raises(ValueError):
        Variant("ell", 256, 256, 16, 64, 8, "shared")
