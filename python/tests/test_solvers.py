"""Oracle tests for the solve-kind lowerings (SpTRSV, SymGS).

Scipy-free numpy references: forward/backward substitution and the
in-place symmetric Gauss-Seidel sweep, both in float64 so the oracles are
strictly more accurate than the float32 kernels under test.
"""

import numpy as np
import pytest

from compile import model
from compile.kernels import sptrsv
from compile.kernels.common import Variant


def np_sptrsv(a, b, lower):
    """Float64 substitution over the triangle of ``a`` incl. diagonal."""
    a = a.astype(np.float64)
    n = len(b)
    x = np.zeros(n)
    for i in range(n) if lower else range(n - 1, -1, -1):
        s = a[i, :i] @ x[:i] if lower else a[i, i + 1:] @ x[i + 1:]
        x[i] = (b[i] - s) / a[i, i]
    return x


def np_symgs(a, b):
    """Float64 forward + backward Gauss-Seidel passes from x = 0."""
    a = a.astype(np.float64)
    n = len(b)
    x = np.zeros(n)
    for order in (range(n), range(n - 1, -1, -1)):
        for i in order:
            s = a[i] @ x - a[i, i] * x[i]
            x[i] = (b[i] - s) / a[i, i]
    return x


def dd_system(rng, n, density=0.2):
    """Sparse, diagonally dominant float32 system (well conditioned for
    both substitution and Gauss-Seidel)."""
    a = rng.standard_normal((n, n)).astype(np.float32)
    a[rng.random((n, n)) > density] = 0.0
    np.fill_diagonal(a, np.abs(a).sum(axis=1) + 1.0)
    return a


def pad_dense(a, b, rows):
    """Bucket-pad the dense operands per the fallback padding contract."""
    n = len(b)
    ap = np.eye(rows, dtype=np.float32)
    ap[:n, :n] = a
    bp = np.zeros(rows, np.float32)
    bp[:n] = b
    return ap, bp


CSR_LO = Variant("csr", 64, 64, 256, 0, 64, "resident", extra=(("lo", 1),))
CSR_UP = Variant("csr", 64, 64, 256, 0, 64, "resident", extra=(("lo", 0),))
ELL_LO = Variant("ell", 64, 64, 8, 16, 4, "resident", extra=(("lo", 1),))


@pytest.mark.parametrize("v", [CSR_LO, CSR_UP], ids=["lower", "upper"])
def test_csr_level_scheduled_solve_matches_oracle(rng, v):
    fn, example = model.build_sptrsv(v)
    n = 48
    a = dd_system(rng, n)
    b = rng.standard_normal(n).astype(np.float32)
    vals, rows, cols, diag, level = sptrsv.pack_csr(a, v)
    bp = np.zeros(v.rows, np.float32)
    bp[:n] = b
    assert [tuple(s.shape) for s in example] == \
        [vals.shape, rows.shape, cols.shape, diag.shape, level.shape, bp.shape]
    (x,) = fn(vals, rows, cols, diag, level, bp)
    x = np.asarray(x)
    lower = bool(v.extra_map["lo"])
    want = np_sptrsv(a, b, lower)
    np.testing.assert_allclose(x[:n], want, rtol=1e-4, atol=1e-5)
    # padded rows solve to exact zeros
    assert not x[n:].any()
    # levels are a real schedule, not the trivial one-row-per-level chain
    n_levels = int(level[:n].max()) + 1
    assert n_levels < n, "a sparse triangle must expose level parallelism"


@pytest.mark.parametrize("fmt", ["ell", "sell", "bell"])
@pytest.mark.parametrize("lo", [1, 0], ids=["lower", "upper"])
def test_dense_fallback_solve_matches_oracle(rng, fmt, lo):
    extra = {"ell": (), "sell": (("h", 8),), "bell": (("bh", 8), ("bw", 8))}[fmt]
    v = Variant(fmt, 48, 48, 8, 4, 4, "resident", extra=extra + (("lo", lo),))
    fn, example = model.build_sptrsv(v)
    assert [tuple(s.shape) for s in example] == [(48, 48), (48,)]
    n = 40
    a = dd_system(rng, n)
    b = rng.standard_normal(n).astype(np.float32)
    ap, bp = pad_dense(a, b, v.rows)
    (x,) = fn(ap, bp)
    want = np_sptrsv(a, b, bool(lo))
    np.testing.assert_allclose(np.asarray(x)[:n], want, rtol=1e-4, atol=1e-5)


def test_lower_upper_equivalence_under_reversal(rng):
    """Solving the upper triangle of A equals solving the lower triangle
    of the fully reversed matrix J A J, read backwards — the classic
    substitution identity, pinning that the two sides are genuine
    mirror lowerings rather than independent algorithms."""
    n = 32
    a = dd_system(rng, n)
    b = rng.standard_normal(n).astype(np.float32)
    v_up = Variant("csr", 32, 32, 128, 0, 32, "resident", extra=(("lo", 0),))
    v_lo = Variant("csr", 32, 32, 128, 0, 32, "resident", extra=(("lo", 1),))
    fn_up, _ = model.build_sptrsv(v_up)
    fn_lo, _ = model.build_sptrsv(v_lo)
    (x_up,) = fn_up(*sptrsv.pack_csr(a, v_up), b)
    (x_lo,) = fn_lo(*sptrsv.pack_csr(a[::-1, ::-1].copy(), v_lo), b[::-1].copy())
    np.testing.assert_allclose(
        np.asarray(x_up), np.asarray(x_lo)[::-1], rtol=1e-4, atol=1e-5
    )


def test_singular_diagonal_is_a_packing_error(rng):
    a = dd_system(rng, 16)
    a[7, 7] = 0.0
    with pytest.raises(ValueError, match="singular system: row 7"):
        sptrsv.pack_csr(a, CSR_LO)
    with pytest.raises(ValueError, match="singular system: row 7"):
        sptrsv.pack_csr(a, CSR_UP)
    # non-square and bucket-overflow guards
    with pytest.raises(ValueError, match="square"):
        sptrsv.pack_csr(np.ones((3, 4), np.float32), CSR_LO)
    with pytest.raises(ValueError, match="exceed bucket"):
        sptrsv.pack_csr(dd_system(rng, 65, density=1.0), CSR_LO)


@pytest.mark.parametrize("fmt,extra", [
    ("csr", ()),
    ("ell", ()),
    ("sell", (("h", 8),)),
    ("bell", (("bh", 8), ("bw", 8))),
])
def test_symgs_sweep_matches_oracle(rng, fmt, extra):
    v = Variant(fmt, 48, 48, 8, 4, 4, "resident", extra=extra)
    fn, example = model.build_symgs(v)
    assert [tuple(s.shape) for s in example] == [(48, 48), (48,)]
    n = 44
    a = dd_system(rng, n)
    b = rng.standard_normal(n).astype(np.float32)
    ap, bp = pad_dense(a, b, v.rows)
    (x,) = fn(ap, bp)
    x = np.asarray(x)
    want = np_symgs(a, b)
    np.testing.assert_allclose(x[:n], want, rtol=1e-4, atol=1e-5)
    assert not x[n:].any(), "padded rows must sweep to exact zeros"
    # one symmetric sweep on a diagonally dominant system is a real
    # smoother: the residual must shrink from the x = 0 starting point
    resid = np.linalg.norm(a @ x[:n] - b)
    assert resid < 0.5 * np.linalg.norm(b)


def test_solve_variant_grids_cover_both_sides_and_all_formats():
    for quick in (True, False):
        tri = model.sptrsv_variants(quick=quick)
        sides = {v.extra_map["lo"] for v in tri}
        assert sides == {0, 1}, f"quick={quick}: both triangle sides"
        gs = model.symgs_variants(quick=quick)
        assert all("lo" not in v.extra_map for v in gs), "symgs is side-free"
        if not quick:
            assert {v.fmt for v in tri} == {"csr", "ell", "sell", "bell"}
            assert {v.fmt for v in gs} == {"csr", "ell", "sell", "bell"}
        names = [f"sptrsv_{v.name}" for v in tri] + [f"symgs_{v.name}" for v in gs]
        assert len(names) == len(set(names)), "solve artifact names collide"
