"""AOT pipeline tests: HLO text generation + manifest schema.

These validate the L2->L3 interchange contract the Rust runtime depends on
(HLO text parseable by xla_extension 0.5.1; manifest columns).
"""

import os
import subprocess
import sys

import jax

from compile import aot, model
from compile.kernels.common import Variant


def test_to_hlo_text_smoke():
    v = Variant("ell", 64, 64, 8, 16, 4, "resident")
    fn, example = model.build_spmv(v)
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    assert "HloModule" in text
    assert "ENTRY" in text
    # must be plain text, not a serialized proto
    assert text.isprintable() or "\n" in text


def test_input_spec_format():
    v = Variant("ell", 64, 64, 8, 16, 4, "resident")
    _, example = model.build_spmv(v)
    spec = aot.input_spec(example)
    assert spec == "f32:64x8,i32:64x8,f32:64"


def test_extra_str():
    v = Variant("bell", 64, 64, 4, 4, 2, "resident", extra=(("bh", 8), ("bw", 8)))
    assert aot.extra_str(v) == "bh=8;bw=8"
    v2 = Variant("ell", 64, 64, 8, 16, 4, "resident")
    assert aot.extra_str(v2) == "-"


def test_manifest_only_writes_schema_without_lowering(tmp_path):
    """--manifest-only is the CI schema-gate fixture generator: full
    manifest (spmv + knob-swept spmm + power rows), no HLO files."""
    out = tmp_path / "fixture"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--quick", "--manifest-only",
         "--out-dir", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    lines = (out / "manifest.tsv").read_text().strip().splitlines()
    rows = [l.split("\t") for l in lines[1:]]
    spmm = [r_ for r_ in rows if r_[1] == "spmm"]
    assert len(spmm) >= 2, "quick inventory must emit spmm rows"
    assert {r_[8] for r_ in spmm} >= {"resident", "gather"}, \
        "the spmm knob sweep must reach the manifest"
    assert all("nc=" in r_[9] for r_ in spmm)
    # the solve kernel classes reach the manifest: sptrsv rows for both
    # triangle sides (lo extra), side-free symgs rows, and names unique
    # across kinds (the Rust engine caches executables by name)
    tri = [r_ for r_ in rows if r_[1] == "sptrsv"]
    assert tri, "quick inventory must emit sptrsv rows"
    assert {("lo=1" in r_[9], "lo=0" in r_[9]) for r_ in tri} == \
        {(True, False), (False, True)}, "both triangle sides must be emitted"
    gs = [r_ for r_ in rows if r_[1] == "symgs"]
    assert gs, "quick inventory must emit symgs rows"
    assert all("lo=" not in r_[9] for r_ in gs), "symgs is side-free"
    names = [r_[0] for r_ in rows]
    assert len(names) == len(set(names)), "manifest names must be unique"
    # no lowering happened: no HLO files AND no Makefile sentinel (the
    # sentinel would mark this schema-only directory as a built
    # inventory and suppress the real lowering)
    names = {p.name for p in out.iterdir()}
    assert names == {"manifest.tsv"}, names


def test_manifest_only_refuses_to_clobber_a_lowered_inventory(tmp_path):
    """A directory holding the sentinel of a real (lowered) inventory
    must be protected: --manifest-only would replace its manifest with
    rows whose HLO files were never generated."""
    out = tmp_path / "artifacts"
    out.mkdir()
    (out / "model.hlo.txt").write_text("# auto-spmv artifact sentinel; 5 artifacts\n")
    (out / "manifest.tsv").write_text("real inventory\n")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--quick", "--manifest-only",
         "--out-dir", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=300,
    )
    assert r.returncode != 0
    assert "refuses to clobber" in r.stderr
    assert (out / "manifest.tsv").read_text() == "real inventory\n", \
        "the lowered inventory's manifest must be untouched"


def test_quick_aot_end_to_end(tmp_path):
    """Run the real module entry point with --quick into a temp dir."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--quick", "--out-dir", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.tsv").read_text().strip().splitlines()
    header = manifest[0].split("\t")
    assert header == ["name", "kind", "fmt", "rows", "cols", "width",
                      "block_rows", "chunk_width", "x_placement", "extra",
                      "path", "inputs"]
    rows = [l.split("\t") for l in manifest[1:]]
    assert len(rows) >= 5
    for r_ in rows:
        assert len(r_) == len(header)
        assert (out / r_[10]).exists()
        assert "HloModule" in (out / r_[10]).read_text()[:200]
