"""AOT pipeline tests: HLO text generation + manifest schema.

These validate the L2->L3 interchange contract the Rust runtime depends on
(HLO text parseable by xla_extension 0.5.1; manifest columns).
"""

import os
import subprocess
import sys

import jax

from compile import aot, model
from compile.kernels.common import Variant


def test_to_hlo_text_smoke():
    v = Variant("ell", 64, 64, 8, 16, 4, "resident")
    fn, example = model.build_spmv(v)
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    assert "HloModule" in text
    assert "ENTRY" in text
    # must be plain text, not a serialized proto
    assert text.isprintable() or "\n" in text


def test_input_spec_format():
    v = Variant("ell", 64, 64, 8, 16, 4, "resident")
    _, example = model.build_spmv(v)
    spec = aot.input_spec(example)
    assert spec == "f32:64x8,i32:64x8,f32:64"


def test_extra_str():
    v = Variant("bell", 64, 64, 4, 4, 2, "resident", extra=(("bh", 8), ("bw", 8)))
    assert aot.extra_str(v) == "bh=8;bw=8"
    v2 = Variant("ell", 64, 64, 8, 16, 4, "resident")
    assert aot.extra_str(v2) == "-"


def test_quick_aot_end_to_end(tmp_path):
    """Run the real module entry point with --quick into a temp dir."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--quick", "--out-dir", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.tsv").read_text().strip().splitlines()
    header = manifest[0].split("\t")
    assert header == ["name", "kind", "fmt", "rows", "cols", "width",
                      "block_rows", "chunk_width", "x_placement", "extra",
                      "path", "inputs"]
    rows = [l.split("\t") for l in manifest[1:]]
    assert len(rows) >= 5
    for r_ in rows:
        assert len(r_) == len(header)
        assert (out / r_[10]).exists()
        assert "HloModule" in (out / r_[10]).read_text()[:200]
