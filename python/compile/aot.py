"""AOT driver: lower every compile variant to HLO text + write the manifest.

Interchange format is HLO **text**, never ``HloModuleProto.serialize()``:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Outputs under ``--out-dir`` (default ../artifacts):
  * ``<variant>.hlo.txt``      — one HLO module per variant (spmv graph),
  * ``spmm_<variant>.hlo.txt`` — multi-vector (batched) SpMM artifacts,
  * ``sptrsv_<variant>.hlo.txt`` — triangular-solve artifacts (both
                                 triangle sides via the ``lo`` extra),
  * ``symgs_<variant>.hlo.txt``— symmetric Gauss-Seidel sweep artifacts,
  * ``power_<variant>.hlo.txt``— power-iteration-step artifacts,
  * ``manifest.tsv``           — one row per artifact; parsed by
                                 ``rust/src/runtime/artifacts.rs``.

Manifest columns (tab-separated):
  name kind fmt rows cols width block_rows chunk_width x_placement extra path inputs
where ``extra``  = semicolon-joined k=v (or '-'),
      ``inputs`` = comma-joined dtype:shape specs, e.g. f32:256x16,i32:256x16,f32:256
"""

import argparse
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.common import Variant

_DTYPE = {"float32": "f32", "int32": "i32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def input_spec(example) -> str:
    parts = []
    for s in example:
        dt = _DTYPE[str(s.dtype)]
        shape = "x".join(str(d) for d in s.shape)
        parts.append(f"{dt}:{shape}")
    return ",".join(parts)


def extra_str(v: Variant) -> str:
    parts = [f"{k}={val}" for k, val in v.extra]
    if v.ncols > 1:
        # batch bucket of an SpMM artifact; parsed by artifacts.rs as
        # ArtifactSpec::ncols()
        parts.append(f"nc={v.ncols}")
    return ";".join(parts) if parts else "-"


def artifact_name(v: Variant, kind: str) -> str:
    prefix = "" if kind == "spmv" else f"{kind}_"
    return f"{prefix}{v.name}.hlo.txt"


def lower_one(build, v: Variant, out_dir: str, kind: str) -> str:
    fn, example = build(v)
    lowered = jax.jit(fn).lower(*example)
    text = to_hlo_text(lowered)
    fname = artifact_name(v, kind)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return fname


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--quick", action="store_true",
                    help="compile only the minimal CI subset")
    ap.add_argument("--manifest-only", action="store_true",
                    help="write manifest.tsv without lowering any HLO "
                         "(CI schema-drift gate: the emitted rows are "
                         "round-tripped through the Rust parser)")
    # legacy flag kept so `python -m compile.aot --out X` still works
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    if args.manifest_only and os.path.exists(os.path.join(out_dir, "model.hlo.txt")):
        # the sentinel marks a LOWERED inventory: replacing its manifest
        # with schema-only rows (and no HLO) would silently shrink or
        # break the artifact set the runtime serves from
        ap.error(f"--manifest-only refuses to clobber the lowered inventory "
                 f"in {out_dir}; pick a fresh --out-dir")
    os.makedirs(out_dir, exist_ok=True)

    def emit(build, v: Variant, kind: str) -> str:
        if args.manifest_only:
            return artifact_name(v, kind)
        return lower_one(build, v, out_dir, kind)

    rows = []
    t0 = time.time()
    variants = model.default_variants(quick=args.quick)
    for i, v in enumerate(variants):
        fname = emit(model.build_spmv, v, "spmv")
        _, example = model.build_spmv(v)
        rows.append((v, "spmv", fname, input_spec(example)))
        print(f"[{i + 1}/{len(variants)}] {fname}", file=sys.stderr)

    for v in model.spmm_variants(quick=args.quick):
        fname = emit(model.build_spmm, v, "spmm")
        _, example = model.build_spmm(v)
        rows.append((v, "spmm", fname, input_spec(example)))
        print(f"[spmm] {fname}", file=sys.stderr)

    for v in model.sptrsv_variants(quick=args.quick):
        fname = emit(model.build_sptrsv, v, "sptrsv")
        _, example = model.build_sptrsv(v)
        rows.append((v, "sptrsv", fname, input_spec(example)))
        print(f"[sptrsv] {fname}", file=sys.stderr)

    for v in model.symgs_variants(quick=args.quick):
        fname = emit(model.build_symgs, v, "symgs")
        _, example = model.build_symgs(v)
        rows.append((v, "symgs", fname, input_spec(example)))
        print(f"[symgs] {fname}", file=sys.stderr)

    for v in model.power_step_variants(quick=args.quick):
        fname = emit(model.build_power_step, v, "power")
        _, example = model.build_power_step(v)
        rows.append((v, "power", fname, input_spec(example)))
        print(f"[power] {fname}", file=sys.stderr)

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("name\tkind\tfmt\trows\tcols\twidth\tblock_rows\tchunk_width"
                "\tx_placement\textra\tpath\tinputs\n")
        for v, kind, fname, spec in rows:
            # non-spmv rows prefix the kind into the manifest name: the
            # Rust engine caches compiled executables BY NAME, so a
            # solve/power row sharing a variant name with its spmv
            # sibling would silently serve the wrong executable
            name = v.name if kind == "spmv" else f"{kind}_{v.name}"
            f.write(
                f"{name}\t{kind}\t{v.fmt}\t{v.rows}\t{v.cols}\t{v.width}"
                f"\t{v.block_rows}\t{v.chunk_width}\t{v.x_placement}"
                f"\t{extra_str(v)}\t{fname}\t{spec}\n"
            )
    if args.manifest_only:
        # no sentinel: nothing was lowered, so the Makefile dependency
        # rule must still consider this directory unbuilt
        print(f"wrote manifest only ({len(rows)} rows, no HLO lowered) "
              f"to {out_dir} in {time.time() - t0:.1f}s", file=sys.stderr)
        return
    # sentinel consumed by the Makefile dependency rule
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write(f"# auto-spmv artifact sentinel; {len(rows)} artifacts\n")
    print(f"wrote {len(rows)} artifacts + manifest to {out_dir} "
          f"in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
