"""ELL SpMV as a Pallas kernel.

GPU original (paper §2.3): one thread per row, column-major ELL arrays for
coalescing. TPU rethink (DESIGN.md §Hardware-Adaptation): a grid of
(row-tiles x width-chunks); each step stages a (block_rows, chunk_width)
tile of ``data``/``cols`` in VMEM and accumulates partial row sums into a
revisited output block — the HBM<->VMEM schedule that CUDA expressed with
thread blocks is expressed here with BlockSpecs.

x placements:
  * ``resident``  — x lives whole in VMEM every step (big "shared memory").
  * ``gather``    — x is gathered outside the kernel at L2 level; the
                    kernel consumes a dense pre-gathered tile (models
                    leaning on the cache hierarchy).
  * ``streamed``  — x is consumed in ``x_seg``-sized segments along a third
                    grid axis with masking (models a small-L1 carve-out).

All variants are numerically identical to ``ref.ell_spmv``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import Variant


def _kernel_resident(d_ref, c_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    d = d_ref[...]
    c = c_ref[...]
    o_ref[...] += jnp.sum(d * x[c], axis=1)


def _kernel_gather(d_ref, xg_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(d_ref[...] * xg_ref[...], axis=1)


def _kernel_streamed(d_ref, c_ref, xs_ref, o_ref, *, x_seg):
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, s == 0))
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...]
    c = c_ref[...]
    xs = xs_ref[...]  # (x_seg,) segment s of x
    base = s * x_seg
    local = c - base
    in_seg = (local >= 0) & (local < x_seg)
    xv = jnp.where(in_seg, xs[jnp.clip(local, 0, x_seg - 1)], 0.0)
    o_ref[...] += jnp.sum(d * xv, axis=1)


def _kernel_spmm_resident(d_ref, c_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (k, m): one input vector per row
    d = d_ref[...]  # (br, cw)
    c = c_ref[...]  # (br, cw)
    # x[:, c] gathers per vector: (k, br, cw); row sums per vector.
    o_ref[...] += jnp.sum(d[None, :, :] * x[:, c], axis=2)


def _kernel_spmm_gather(d_ref, xg_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(d_ref[...][None, :, :] * xg_ref[...], axis=2)


def _build_spmm(v: Variant):
    """SpMM lowering: Y = A X for a batch bucket of ``v.ncols`` vectors.

    fn(data f32[rows, width], cols i32[rows, width], x f32[ncols, cols])
      -> (y f32[ncols, rows],)

    The matrix tiles stream through VMEM exactly once per launch; every
    input vector rides the same tile schedule (the SpMV -> SpMM
    amortization the serving pool's coalescing exists for).
    """
    n, m, w, k = v.rows, v.cols, v.width, v.ncols
    br, cw = v.block_rows, v.chunk_width
    assert n % br == 0 and w % cw == 0, (v.name, "grid must divide shapes")
    grid = (n // br, w // cw)

    d_spec = pl.BlockSpec((br, cw), lambda i, j: (i, j))
    c_spec = pl.BlockSpec((br, cw), lambda i, j: (i, j))
    o_spec = pl.BlockSpec((k, br), lambda i, j: (0, i))
    out_shape = jax.ShapeDtypeStruct((k, n), jnp.float32)

    if v.x_placement == "resident":
        x_spec = pl.BlockSpec((k, m), lambda i, j: (0, 0))
        call = pl.pallas_call(
            _kernel_spmm_resident,
            grid=grid,
            in_specs=[d_spec, c_spec, x_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, cols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((k, br, cw), lambda i, j: (0, i, j))
        call = pl.pallas_call(
            _kernel_spmm_gather,
            grid=grid,
            in_specs=[d_spec, xg_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, x[:, cols]),)

    else:
        raise ValueError(f"ELL SpMM does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((n, w), jnp.float32),
        jax.ShapeDtypeStruct((n, w), jnp.int32),
        jax.ShapeDtypeStruct((k, m), jnp.float32),
    )
    return fn, example


def build(v: Variant):
    """Return (fn, example_args) for this ELL variant.

    fn(data f32[rows, width], cols i32[rows, width], x f32[cols]) -> (y f32[rows],)
    (``ncols > 1`` lowers the SpMM form instead, see ``_build_spmm``.)
    """
    if v.ncols > 1:
        return _build_spmm(v)
    n, m, w = v.rows, v.cols, v.width
    br, cw = v.block_rows, v.chunk_width
    assert n % br == 0 and w % cw == 0, (v.name, "grid must divide shapes")
    grid_w = w // cw

    d_spec = pl.BlockSpec((br, cw), lambda i, j: (i, j))
    c_spec = pl.BlockSpec((br, cw), lambda i, j: (i, j))
    o_spec = pl.BlockSpec((br,), lambda i, j: (i,))

    if v.x_placement == "resident":
        x_spec = pl.BlockSpec((m,), lambda i, j: (0,))
        call = pl.pallas_call(
            _kernel_resident,
            grid=(n // br, grid_w),
            in_specs=[d_spec, c_spec, x_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, cols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((br, cw), lambda i, j: (i, j))
        call = pl.pallas_call(
            _kernel_gather,
            grid=(n // br, grid_w),
            in_specs=[d_spec, xg_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, cols, x):
            # L2-level gather: models relying on the cache for x accesses.
            return (call(data, x[cols]),)

    elif v.x_placement == "streamed":
        x_seg = v.extra_map.get("xseg", max(m // 4, 1))
        assert m % x_seg == 0, (v.name, "x_seg must divide cols")
        d_spec3 = pl.BlockSpec((br, cw), lambda i, j, s: (i, j))
        c_spec3 = pl.BlockSpec((br, cw), lambda i, j, s: (i, j))
        xs_spec = pl.BlockSpec((x_seg,), lambda i, j, s: (s,))
        o_spec3 = pl.BlockSpec((br,), lambda i, j, s: (i,))
        call = pl.pallas_call(
            functools.partial(_kernel_streamed, x_seg=x_seg),
            grid=(n // br, grid_w, m // x_seg),
            in_specs=[d_spec3, c_spec3, xs_spec],
            out_specs=o_spec3,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, cols, x),)

    else:  # pragma: no cover
        raise ValueError(v.x_placement)

    example = (
        jax.ShapeDtypeStruct((n, w), jnp.float32),
        jax.ShapeDtypeStruct((n, w), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )
    return fn, example
