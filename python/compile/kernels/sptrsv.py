"""Sparse triangular solve (SpTRSV) lowerings.

GPU original (HPCG-style solvers): forward/backward substitution over the
lower/upper triangle of ``A`` *including the diagonal*, with stored entries
strictly on the wrong side ignored — so a full matrix solves with its
triangle. The dependency chain (row ``i`` needs every in-triangle ``x[j]``
first) is what makes SpTRSV hard to parallelize; the standard answer is
**level scheduling**: rows are grouped into levels where level ``l`` rows
depend only on rows of levels ``< l``, so each level solves in parallel.

TPU rethink, per format:

* **CSR** — a Pallas kernel sweeping the levels along the grid axis. The
  host pre-expands the in-triangle off-diagonal entries to COO triplets
  (same marshalling family as the CSR SpMV kernel) plus a dense diagonal
  and a per-row level index. Each grid step scatter-accumulates ALL
  triplet products against the current iterate and commits the candidate
  ``(b - acc) / diag`` only to the rows of its level — rows of earlier
  levels already hold their final values, so the masked update is exact.
  The grid is sized ``rows`` (the worst-case chain length); steps past
  ``n_levels`` are fixpoint no-ops.
* **ELL / SELL / BELL** — the padded column-major layouts cannot express
  the row-to-row dependency chain in a static BlockSpec sweep, so these
  lower a **dense fallback**: ``A`` realized dense, substitution as a
  ``lax.fori_loop`` over rows. Same numerics, one artifact per format so
  per-format artifact selection stays uniform.

The triangle side is the ``lo`` extra (``lo=1`` lower/forward, ``lo=0``
upper/backward), mirrored by ``ArtifactSpec::lower()`` on the Rust side.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import Variant


def _lower(v: Variant) -> bool:
    return bool(v.extra_map.get("lo", 1))


def _kernel_levels(v_ref, r_ref, c_ref, d_ref, lvl_ref, b_ref, o_ref, *, n):
    """One grid step = one level of the schedule."""
    l = pl.program_id(0)

    @pl.when(l == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = o_ref[...]
    vals = v_ref[...]
    rows = r_ref[...]
    cols = c_ref[...]
    # in-triangle contributions against the current iterate; rows of this
    # level only reference already-final columns, the rest is discarded
    acc = jnp.zeros((n,), vals.dtype).at[rows].add(vals * x[cols])
    cand = (b_ref[...] - acc) / d_ref[...]
    o_ref[...] = jnp.where(lvl_ref[...] == l, cand, x)


def _build_csr(v: Variant):
    """Level-scheduled CSR solve.

    fn(vals f32[nnz], rows i32[nnz], cols i32[nnz], diag f32[n],
       level i32[n], b f32[n]) -> (x f32[n],)

    ``width`` keeps the CSR bucket semantics (padded in-triangle triplet
    count); padding entries are (0.0, row 0, col 0), padded rows carry
    diag 1.0 / level 0 / b 0.0 so they solve to exact zeros.
    """
    n, nnz = v.rows, v.width
    tri_spec = pl.BlockSpec((nnz,), lambda l: (0,))
    vec_spec = pl.BlockSpec((n,), lambda l: (0,))
    call = pl.pallas_call(
        functools.partial(_kernel_levels, n=n),
        grid=(n,),  # worst-case chain: one row per level
        in_specs=[tri_spec, tri_spec, tri_spec, vec_spec, vec_spec, vec_spec],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )

    def fn(vals, rows, cols, diag, level, b):
        return (call(vals, rows, cols, diag, level, b),)

    example = (
        jax.ShapeDtypeStruct((nnz,), jnp.float32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    return fn, example


def _build_dense(v: Variant):
    """Dense-fallback substitution for the padded column formats.

    fn(a f32[n, n], b f32[n]) -> (x f32[n],)
    """
    n = v.rows
    lower = _lower(v)
    idx = jnp.arange(n)

    def fn(a, b):
        a = jnp.asarray(a)
        b = jnp.asarray(b)

        def body(step, x):
            i = step if lower else n - 1 - step
            mask = idx < i if lower else idx > i
            acc = b[i] - jnp.sum(jnp.where(mask, a[i] * x, 0.0))
            return x.at[i].set(acc / a[i, i])

        x = jax.lax.fori_loop(0, n, body, jnp.zeros((n,), jnp.float32))
        return (x,)

    example = (
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    return fn, example


def build(v: Variant):
    """Return (fn, example_args) for this SpTRSV variant."""
    if v.fmt == "csr":
        return _build_csr(v)
    return _build_dense(v)


# ---------------------------------------------------------------------------
# Host-side marshalling (reference path; the Rust runtime marshals its own
# CSR the same way when it adopts the compiled solve artifacts)
# ---------------------------------------------------------------------------

def pack_csr(a: "np.ndarray", v: Variant):
    """Marshal a dense-realized matrix into the level-scheduled operands.

    Keeps only the strictly in-triangle off-diagonal entries (wrong-side
    entries are ignored, HPCG-style), extracts the dense diagonal, and
    computes the level schedule ``level[i] = 1 + max(level[j])`` over the
    in-triangle dependencies.

    Raises ``ValueError`` for a non-square matrix, a bucket overflow, or
    a zero diagonal — the singular case, mirroring the Rust native
    fallback's "singular system" error.
    """
    n = a.shape[0]
    if a.shape[1] != n:
        raise ValueError(f"sptrsv needs a square matrix, got {a.shape}")
    if n > v.rows:
        raise ValueError(f"matrix rows {n} exceed bucket {v.rows} ({v.name})")
    lower = _lower(v)

    diag = np.ones(v.rows, np.float32)
    level = np.zeros(v.rows, np.int32)
    vals, rows, cols = [], [], []
    order = range(n) if lower else range(n - 1, -1, -1)
    for i in order:
        if a[i, i] == 0.0:
            raise ValueError(
                f"singular system: row {i} has no nonzero diagonal entry"
            )
        diag[i] = a[i, i]
        deps = 0
        js = range(i) if lower else range(i + 1, n)
        for j in js:
            if a[i, j] != 0.0:
                vals.append(a[i, j])
                rows.append(i)
                cols.append(j)
                deps = max(deps, level[j] + 1)
        level[i] = deps
    if len(vals) > v.width:
        raise ValueError(
            f"in-triangle nnz {len(vals)} exceed bucket width {v.width} ({v.name})"
        )

    pad = v.width - len(vals)
    return (
        np.asarray(vals + [0.0] * pad, np.float32),
        np.asarray(rows + [0] * pad, np.int32),
        np.asarray(cols + [0] * pad, np.int32),
        diag,
        level,
    )
