"""CSR SpMV as a Pallas kernel.

GPU original (paper §2.3): CSR-vector — one warp per row walking
``row_ptr[i]..row_ptr[i+1]`` with an intra-warp reduction; load-imbalanced
when row lengths vary. TPU rethink: dynamic per-row extents don't map to
static BlockSpecs, so the host pre-expands CSR to COO triplets
(``rust/src/sparse/csr.rs::to_kernel_coo``) and the kernel walks fixed-size
nnz chunks along a single grid axis, scatter-accumulating each chunk's
products into the full output vector kept resident in VMEM. The warp-level
segmented reduction of the GPU becomes a chunk-level ``.at[].add`` segment
sum — same algorithm, expressed for a vector unit instead of 32-lane warps.

Layout: vals f32[nnz_pad], rows i32[nnz_pad], cols i32[nnz_pad]; padding
entries are (0.0, row 0, col 0).

x placements: ``resident`` (x whole in VMEM) and ``gather``
(x pre-gathered per nnz entry at L2 — models cache-served random reads).

Knobs: ``chunk_width`` = nnz per grid step; ``block_rows`` is accepted for
interface parity but the output is one revisited block (the scatter needs
the whole y in scope).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import Variant


def _kernel_resident(v_ref, r_ref, c_ref, x_ref, o_ref, *, n):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...]
    rows = r_ref[...]
    cols = c_ref[...]
    x = x_ref[...]
    contrib = jnp.zeros((n,), vals.dtype).at[rows].add(vals * x[cols])
    o_ref[...] += contrib


def _kernel_gather(v_ref, r_ref, xg_ref, o_ref, *, n):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...]
    rows = r_ref[...]
    contrib = jnp.zeros((n,), vals.dtype).at[rows].add(vals * xg_ref[...])
    o_ref[...] += contrib


def _kernel_spmm_resident(v_ref, r_ref, c_ref, x_ref, o_ref, *, n, nv):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...]
    rows = r_ref[...]
    cols = c_ref[...]
    x = x_ref[...]  # (nv, m): one input vector per row
    # per-vector scatter of this chunk's products: (nv, cw) into (nv, n)
    contrib = jnp.zeros((nv, n), vals.dtype).at[:, rows].add(vals[None, :] * x[:, cols])
    o_ref[...] += contrib


def _kernel_spmm_gather(v_ref, r_ref, xg_ref, o_ref, *, n, nv):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...]
    rows = r_ref[...]
    contrib = jnp.zeros((nv, n), vals.dtype).at[:, rows].add(vals[None, :] * xg_ref[...])
    o_ref[...] += contrib


def _build_spmm(v: Variant):
    """SpMM lowering: Y = A X for a batch bucket of ``v.ncols`` vectors.

    fn(vals f32[nnz], rows i32[nnz], cols i32[nnz], x f32[ncols, cols])
      -> (y f32[ncols, rows],)

    The COO triplet stream is walked once per launch; each chunk's
    products scatter into all ``ncols`` output rows at once.
    """
    import functools

    n, m, nnz, nv = v.rows, v.cols, v.width, v.ncols
    cw = v.chunk_width
    assert nnz % cw == 0, (v.name, "chunk must divide nnz_pad")
    grid = (nnz // cw,)

    tri_spec = pl.BlockSpec((cw,), lambda k: (k,))
    o_spec = pl.BlockSpec((nv, n), lambda k: (0, 0))
    out_shape = jax.ShapeDtypeStruct((nv, n), jnp.float32)

    if v.x_placement == "resident":
        x_spec = pl.BlockSpec((nv, m), lambda k: (0, 0))
        call = pl.pallas_call(
            functools.partial(_kernel_spmm_resident, n=n, nv=nv),
            grid=grid,
            in_specs=[tri_spec, tri_spec, tri_spec, x_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(vals, rows, cols, x):
            return (call(vals, rows, cols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((nv, cw), lambda k: (0, k))
        call = pl.pallas_call(
            functools.partial(_kernel_spmm_gather, n=n, nv=nv),
            grid=grid,
            in_specs=[tri_spec, tri_spec, xg_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(vals, rows, cols, x):
            return (call(vals, rows, x[:, cols]),)

    else:
        raise ValueError(f"CSR SpMM does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((nnz,), jnp.float32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((nv, m), jnp.float32),
    )
    return fn, example


def build(v: Variant):
    """Return (fn, example_args) for this CSR variant.

    Shapes: width = nnz_pad (padded triplet count).
    fn(vals f32[nnz], rows i32[nnz], cols i32[nnz], x f32[cols]) -> (y f32[rows],)
    (``ncols > 1`` lowers the SpMM form instead, see ``_build_spmm``.)
    """
    import functools

    if v.ncols > 1:
        return _build_spmm(v)
    n, m, nnz = v.rows, v.cols, v.width
    cw = v.chunk_width
    assert nnz % cw == 0, (v.name, "chunk must divide nnz_pad")
    grid = (nnz // cw,)

    tri_spec = pl.BlockSpec((cw,), lambda k: (k,))
    o_spec = pl.BlockSpec((n,), lambda k: (0,))

    if v.x_placement == "resident":
        x_spec = pl.BlockSpec((m,), lambda k: (0,))
        call = pl.pallas_call(
            functools.partial(_kernel_resident, n=n),
            grid=grid,
            in_specs=[tri_spec, tri_spec, tri_spec, x_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(vals, rows, cols, x):
            return (call(vals, rows, cols, x),)

    elif v.x_placement == "gather":
        call = pl.pallas_call(
            functools.partial(_kernel_gather, n=n),
            grid=grid,
            in_specs=[tri_spec, tri_spec, tri_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(vals, rows, cols, x):
            return (call(vals, rows, x[cols]),)

    else:
        raise ValueError(f"CSR does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((nnz,), jnp.float32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )
    return fn, example
