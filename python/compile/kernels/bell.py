"""Blocked-ELL (BELL) SpMV as a Pallas kernel.

GPU original: one thread block per block-row, dense ``bh x bw`` blocks
multiplied in registers. TPU rethink: blocks are exactly what the MXU
wants — each grid step stages a (block_rows, chunk_width) tile of *blocks*
in VMEM and contracts them with the gathered x blocks via an einsum the
compiler maps onto the systolic array (bf16-able dense contractions, not
scalar per-thread MACs).

Layout: data f32[nb, kb, bh, bw], bcols i32[nb, kb]; padding blocks have
``bcols == 0`` and all-zero data.

x placements: ``resident`` (x whole in VMEM) and ``gather`` (x blocks
pre-gathered at L2: models cache-backed access).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import Variant


def _kernel_resident(d_ref, c_ref, x_ref, o_ref, *, bw):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...]        # (br, ck, bh, bw)
    c = c_ref[...]        # (br, ck)
    x = x_ref[...]        # (m,)
    idx = c[..., None] * bw + jnp.arange(bw)[None, None, :]
    xg = x[idx]           # (br, ck, bw)
    y = jnp.einsum("rkij,rkj->ri", d, xg)  # (br, bh)
    o_ref[...] += y.reshape(o_ref.shape)


def _kernel_gather(d_ref, xg_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    y = jnp.einsum("rkij,rkj->ri", d_ref[...], xg_ref[...])
    o_ref[...] += y.reshape(o_ref.shape)


def _kernel_spmm_resident(d_ref, c_ref, x_ref, o_ref, *, bw):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...]  # (br, ck, bh, bw)
    c = c_ref[...]  # (br, ck)
    x = x_ref[...]  # (nv, m): one input vector per row
    idx = c[..., None] * bw + jnp.arange(bw)[None, None, :]
    xg = x[:, idx]  # (nv, br, ck, bw)
    y = jnp.einsum("rcij,nrcj->nri", d, xg)  # (nv, br, bh)
    o_ref[...] += y.reshape(o_ref.shape)


def _kernel_spmm_gather(d_ref, xg_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    y = jnp.einsum("rcij,nrcj->nri", d_ref[...], xg_ref[...])
    o_ref[...] += y.reshape(o_ref.shape)


def _build_spmm(v: Variant):
    """SpMM lowering: Y = A X for a batch bucket of ``v.ncols`` vectors.

    fn(data f32[nb,kb,bh,bw], bcols i32[nb,kb], x f32[ncols, cols])
      -> (y f32[ncols, rows],)

    The block contractions become one einsum over all ``ncols`` vectors —
    exactly the denser MXU workload batching exists to create.
    """
    import functools

    bh = v.extra_map.get("bh", 8)
    bw = v.extra_map.get("bw", 8)
    n, m, kb, nv = v.rows, v.cols, v.width, v.ncols
    assert n % bh == 0 and m % bw == 0
    nb = n // bh
    br, ck = v.block_rows, v.chunk_width
    assert nb % br == 0 and kb % ck == 0, (v.name, "grid must divide shapes")

    d_spec = pl.BlockSpec((br, ck, bh, bw), lambda i, k: (i, k, 0, 0))
    o_spec = pl.BlockSpec((nv, br * bh), lambda i, k: (0, i))
    out_shape = jax.ShapeDtypeStruct((nv, n), jnp.float32)
    grid = (nb // br, kb // ck)

    if v.x_placement == "resident":
        c_spec = pl.BlockSpec((br, ck), lambda i, k: (i, k))
        x_spec = pl.BlockSpec((nv, m), lambda i, k: (0, 0))
        call = pl.pallas_call(
            functools.partial(_kernel_spmm_resident, bw=bw),
            grid=grid,
            in_specs=[d_spec, c_spec, x_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(data, bcols, x):
            return (call(data, bcols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((nv, br, ck, bw), lambda i, k: (0, i, k, 0))
        call = pl.pallas_call(
            _kernel_spmm_gather,
            grid=grid,
            in_specs=[d_spec, xg_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(data, bcols, x):
            idx = bcols[..., None] * bw + jnp.arange(bw)[None, None, :]
            return (call(data, x[:, idx]),)

    else:
        raise ValueError(f"BELL SpMM does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((nb, kb, bh, bw), jnp.float32),
        jax.ShapeDtypeStruct((nb, kb), jnp.int32),
        jax.ShapeDtypeStruct((nv, m), jnp.float32),
    )
    return fn, example


def build(v: Variant):
    """Return (fn, example_args) for this BELL variant.

    Shapes: rows = nb*bh, width = kb (block-columns per block-row).
    extra: bh (block height), bw (block width).
    fn(data f32[nb,kb,bh,bw], bcols i32[nb,kb], x f32[cols]) -> (y f32[rows],)
    (``ncols > 1`` lowers the SpMM form instead, see ``_build_spmm``.)
    """
    if v.ncols > 1:
        return _build_spmm(v)
    bh = v.extra_map.get("bh", 8)
    bw = v.extra_map.get("bw", 8)
    n, m, kb = v.rows, v.cols, v.width
    assert n % bh == 0 and m % bw == 0
    nb = n // bh
    br, ck = v.block_rows, v.chunk_width  # block-rows and block-cols per step
    assert nb % br == 0 and kb % ck == 0, (v.name, "grid must divide shapes")

    d_spec = pl.BlockSpec((br, ck, bh, bw), lambda i, k: (i, k, 0, 0))
    o_spec = pl.BlockSpec((br * bh,), lambda i, k: (i,))
    grid = (nb // br, kb // ck)

    if v.x_placement == "resident":
        c_spec = pl.BlockSpec((br, ck), lambda i, k: (i, k))
        x_spec = pl.BlockSpec((m,), lambda i, k: (0,))
        import functools

        call = pl.pallas_call(
            functools.partial(_kernel_resident, bw=bw),
            grid=grid,
            in_specs=[d_spec, c_spec, x_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, bcols, x):
            return (call(data, bcols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((br, ck, bw), lambda i, k: (i, k, 0))
        call = pl.pallas_call(
            _kernel_gather,
            grid=grid,
            in_specs=[d_spec, xg_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, bcols, x):
            idx = bcols[..., None] * bw + jnp.arange(bw)[None, None, :]
            return (call(data, x[idx]),)

    else:
        raise ValueError(f"BELL does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((nb, kb, bh, bw), jnp.float32),
        jax.ShapeDtypeStruct((nb, kb), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )
    return fn, example
