"""Sliced-ELL (SELL) SpMV as a Pallas kernel.

GPU original: slices of ``h`` rows, each padded only to its own max row
length, one warp per slice. TPU rethink: the grid walks (slice-tiles x
width-chunks); each step stages a (block_rows slices, h, chunk_width) tile
in VMEM. Because AOT artifacts need static shapes, slices are padded to the
bucket width ``w`` — the *storage* advantage of SELL is modelled on the
Rust side (``rust/src/sparse/sell.rs`` keeps ragged slices; padding happens
only when marshalling into the bucket), while the *compute* schedule here
preserves the slice-local access pattern.

Layout: data f32[ns, h, w], cols i32[ns, h, w]; padding entries are
(0, col 0).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import Variant


def _kernel_resident(d_ref, c_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...]  # (bs, h, cw)
    c = c_ref[...]
    x = x_ref[...]
    y = jnp.sum(d * x[c], axis=2)  # (bs, h)
    o_ref[...] += y.reshape(o_ref.shape)


def _kernel_gather(d_ref, xg_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    y = jnp.sum(d_ref[...] * xg_ref[...], axis=2)
    o_ref[...] += y.reshape(o_ref.shape)


def _kernel_spmm_resident(d_ref, c_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...]  # (bs, h, cw)
    c = c_ref[...]
    x = x_ref[...]  # (k, m): one input vector per row
    y = jnp.sum(d[None, :, :, :] * x[:, c], axis=3)  # (k, bs, h)
    o_ref[...] += y.reshape(o_ref.shape)


def _kernel_spmm_gather(d_ref, xg_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    y = jnp.sum(d_ref[...][None, :, :, :] * xg_ref[...], axis=3)
    o_ref[...] += y.reshape(o_ref.shape)


def _build_spmm(v: Variant):
    """SpMM lowering: Y = A X for a batch bucket of ``v.ncols`` vectors.

    fn(data f32[ns,h,w], cols i32[ns,h,w], x f32[ncols, cols])
      -> (y f32[ncols, rows],)
    """
    h = v.extra_map.get("h", 8)
    n, m, w, k = v.rows, v.cols, v.width, v.ncols
    assert n % h == 0
    ns = n // h
    bs, cw = v.block_rows, v.chunk_width
    assert ns % bs == 0 and w % cw == 0, (v.name, "grid must divide shapes")
    grid = (ns // bs, w // cw)

    d_spec = pl.BlockSpec((bs, h, cw), lambda i, j: (i, 0, j))
    o_spec = pl.BlockSpec((k, bs * h), lambda i, j: (0, i))
    out_shape = jax.ShapeDtypeStruct((k, n), jnp.float32)

    if v.x_placement == "resident":
        c_spec = pl.BlockSpec((bs, h, cw), lambda i, j: (i, 0, j))
        x_spec = pl.BlockSpec((k, m), lambda i, j: (0, 0))
        call = pl.pallas_call(
            _kernel_spmm_resident,
            grid=grid,
            in_specs=[d_spec, c_spec, x_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, cols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((k, bs, h, cw), lambda i, j: (0, i, 0, j))
        call = pl.pallas_call(
            _kernel_spmm_gather,
            grid=grid,
            in_specs=[d_spec, xg_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, x[:, cols]),)

    else:
        raise ValueError(f"SELL SpMM does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((ns, h, w), jnp.float32),
        jax.ShapeDtypeStruct((ns, h, w), jnp.int32),
        jax.ShapeDtypeStruct((k, m), jnp.float32),
    )
    return fn, example


def build(v: Variant):
    """Return (fn, example_args) for this SELL variant.

    Shapes: rows = ns*h, width = w. extra: h (slice height).
    block_rows counts *slices* per grid step.
    fn(data f32[ns,h,w], cols i32[ns,h,w], x f32[cols]) -> (y f32[rows],)
    (``ncols > 1`` lowers the SpMM form instead, see ``_build_spmm``.)
    """
    if v.ncols > 1:
        return _build_spmm(v)
    h = v.extra_map.get("h", 8)
    n, m, w = v.rows, v.cols, v.width
    assert n % h == 0
    ns = n // h
    bs, cw = v.block_rows, v.chunk_width
    assert ns % bs == 0 and w % cw == 0, (v.name, "grid must divide shapes")
    grid = (ns // bs, w // cw)

    d_spec = pl.BlockSpec((bs, h, cw), lambda i, j: (i, 0, j))
    o_spec = pl.BlockSpec((bs * h,), lambda i, j: (i,))

    if v.x_placement == "resident":
        c_spec = pl.BlockSpec((bs, h, cw), lambda i, j: (i, 0, j))
        x_spec = pl.BlockSpec((m,), lambda i, j: (0,))
        call = pl.pallas_call(
            _kernel_resident,
            grid=grid,
            in_specs=[d_spec, c_spec, x_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, cols, x),)

    elif v.x_placement == "gather":
        xg_spec = pl.BlockSpec((bs, h, cw), lambda i, j: (i, 0, j))
        call = pl.pallas_call(
            _kernel_gather,
            grid=grid,
            in_specs=[d_spec, xg_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )

        def fn(data, cols, x):
            return (call(data, x[cols]),)

    else:
        raise ValueError(f"SELL does not support x_placement={v.x_placement}")

    example = (
        jax.ShapeDtypeStruct((ns, h, w), jnp.float32),
        jax.ShapeDtypeStruct((ns, h, w), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
    )
    return fn, example
