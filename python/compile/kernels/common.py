"""Shared definitions for the Auto-SpMV Pallas kernels.

A *variant* is one compile-time configuration of one sparse-format kernel.
It is the TPU analogue of the paper's CUDA compile parameters (see
DESIGN.md §Hardware-Adaptation):

  * ``block_rows``  — rows (or block-rows / slices) per grid step
                      (analogue of thread-block size),
  * ``chunk_width`` — per-step working-set width in VMEM
                      (analogue of ``maxrregcount``: wide = fewer passes
                      but larger on-chip footprint),
  * ``x_placement`` — how the dense vector is staged
                      (analogue of the L1/shared carve-out):
                      ``resident`` = whole x in VMEM each step,
                      ``gather``   = x gathered outside the kernel (models
                      relying on the cache hierarchy),
                      ``streamed`` = x consumed in masked segments
                      (ELL only; models a small-L1 configuration).

Every variant lowers to its own HLO artifact; the Rust router picks among
the compiled executables at run time.
"""

from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax.numpy as jnp

FORMATS = ("csr", "ell", "bell", "sell")
X_PLACEMENTS = ("resident", "gather", "streamed")


@dataclass(frozen=True)
class Variant:
    """One compile-time configuration of one SpMV/SpMM kernel.

    ``ncols`` is the batch bucket: the number of dense input vectors one
    launch consumes. ``ncols == 1`` is the classic SpMV artifact;
    ``ncols > 1`` lowers the SpMM form ``Y = A X`` where ``X`` is
    ``(ncols, cols)`` — one row per input vector, so the serving runtime
    can marshal a coalesced batch as a single contiguous literal and
    execute it in ONE kernel launch (matrix stream amortized across the
    whole batch).
    """

    fmt: str                 # csr | ell | bell | sell
    rows: int                # padded row count of the shape bucket
    cols: int                # padded column count (x length)
    width: int               # ELL/SELL width, BELL block-columns, CSR nnz_pad
    block_rows: int          # rows (ELL/CSR), block-rows (BELL), slices (SELL) per grid step
    chunk_width: int         # VMEM working-set width per grid step
    x_placement: str         # resident | gather | streamed
    ncols: int = 1           # batch bucket: input vectors per launch (1 = SpMV)
    extra: Tuple[Tuple[str, int], ...] = field(default=())  # format-specific

    def __post_init__(self):
        if self.fmt not in FORMATS:
            raise ValueError(f"unknown format {self.fmt!r}")
        if self.x_placement not in X_PLACEMENTS:
            raise ValueError(f"unknown x placement {self.x_placement!r}")
        if self.ncols < 1:
            raise ValueError(f"ncols must be >= 1, got {self.ncols}")

    @property
    def name(self) -> str:
        ex = "".join(f"_{k}{v}" for k, v in self.extra)
        nc = f"_x{self.ncols}" if self.ncols > 1 else ""
        return (
            f"{self.fmt}_r{self.rows}_c{self.cols}_w{self.width}"
            f"_b{self.block_rows}_k{self.chunk_width}_{self.x_placement}{nc}{ex}"
        )

    @property
    def extra_map(self) -> Dict[str, int]:
        return dict(self.extra)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def f32(shape) -> "jnp.ndarray":
    return jnp.zeros(shape, jnp.float32)
