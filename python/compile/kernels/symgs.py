"""Symmetric Gauss-Seidel sweep (SymGS) lowerings.

One sweep is a forward pass (rows ascending) then a backward pass (rows
descending), each updating ``x[i] = (b[i] - sum_{j != i} a_ij x[j]) / a_ii``
in place with the latest values; applied from ``x = 0`` it is the standard
smoother/preconditioner of multigrid and preconditioned CG (the serving
pool's ``Session::symgs_step``).

Unlike SpTRSV there is no level parallelism to recover: the in-place
update chains EVERY row through the previous one (the strict triangle of
dependencies flips between the passes), so no sparse storage format can
express the chain in a static BlockSpec sweep. All formats therefore
lower the **dense fallback** — ``A`` realized dense, both passes as
``lax.fori_loop`` row updates — one artifact per format so per-format
artifact selection stays uniform with the other kernel classes. The
sequential-chain rationale is the documented contract (DESIGN.md §13);
a red/black-colored variant is the natural successor once the generator
grid carries coloring metadata.
"""

import jax
import jax.numpy as jnp

from .common import Variant


def build(v: Variant):
    """Return (fn, example_args) for this SymGS variant.

    fn(a f32[n, n], b f32[n]) -> (x f32[n],)

    Padded rows must carry a unit diagonal (``a[i, i] = 1``) and zero
    ``b`` so they sweep to exact zeros — the same padding contract as the
    SpTRSV dense fallback.
    """
    n = v.rows
    idx = jnp.arange(n)

    def fn(a, b):
        a = jnp.asarray(a)
        b = jnp.asarray(b)

        def update(i, x):
            acc = b[i] - jnp.sum(jnp.where(idx != i, a[i] * x, 0.0))
            return x.at[i].set(acc / a[i, i])

        x = jax.lax.fori_loop(0, n, update, jnp.zeros((n,), jnp.float32))
        x = jax.lax.fori_loop(0, n, lambda s, x: update(n - 1 - s, x), x)
        return (x,)

    example = (
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    return fn, example
