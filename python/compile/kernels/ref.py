"""Pure-jnp correctness oracles for every sparse format.

These are deliberately naive: one expression per format, no tiling, no
Pallas. Every Pallas kernel in this package is tested (pytest + hypothesis)
against the matching oracle, and the oracles themselves are tested against
a dense matmul in ``python/tests/test_ref.py``.

Conventions shared with the Rust substrate (``rust/src/sparse``):
  * padding entries carry ``value == 0`` and a *valid* index (0), so they
    contribute nothing to the product;
  * CSR is pre-expanded to COO triplets on the host (the kernel-side
    representation); padding entries point at row 0 with value 0;
  * BELL stores dense ``bh x bw`` blocks; ``bcols`` are block-column ids;
  * SELL stores slices of height ``h`` padded to a per-bucket width.
"""

import jax.numpy as jnp


def dense_spmv(a, x):
    """y = A @ x for a dense matrix — the oracle's oracle."""
    return a @ x


def coo_spmv(vals, rows, cols, x, n):
    """CSR/COO oracle: scatter-add of vals * x[cols] into rows."""
    return jnp.zeros((n,), x.dtype).at[rows].add(vals * x[cols])


def ell_spmv(data, cols, x):
    """ELL oracle: data (n, w), cols (n, w) -> y (n,)."""
    return jnp.sum(data * x[cols], axis=1)


def bell_spmv(data, bcols, x):
    """BELL oracle: data (nb, kb, bh, bw), bcols (nb, kb) -> y (nb*bh,).

    y[ib*bh:(ib+1)*bh] = sum_k data[ib, k] @ x[bcols[ib, k]*bw : +bw]
    """
    nb, kb, bh, bw = data.shape
    idx = bcols[..., None] * bw + jnp.arange(bw)[None, None, :]
    xg = x[idx]  # (nb, kb, bw)
    y = jnp.einsum("rkij,rkj->ri", data, xg)
    return y.reshape(nb * bh)


def sell_spmv(data, cols, x):
    """SELL oracle: data (ns, h, w), cols (ns, h, w) -> y (ns*h,)."""
    ns, h, w = data.shape
    y = jnp.sum(data * x[cols], axis=2)
    return y.reshape(ns * h)
