"""Layer-2 JAX compute graphs for Auto-SpMV.

Builds, per compile variant, the jittable function the Rust runtime will
execute — the SpMV product itself, plus composed graphs (a power-iteration
step) showing kernels embedding in a larger L2 computation. Everything here
runs ONCE, at build time, inside ``aot.py``; Python never appears on the
request path.

The *default variant set* defined here is the artifact inventory: the TPU
analogue of the paper's compile-parameter sweep (DESIGN.md §2 and §5). The
Rust dataset builder sweeps the same knob names through the GPU simulator;
the run-time router maps its predictions onto these artifact names.
"""

from typing import Callable, List, Tuple

import jax.numpy as jnp

from .kernels import bell, csr, ell, sell, sptrsv, symgs
from .kernels.common import Variant

_BUILDERS = {"ell": ell.build, "bell": bell.build, "sell": sell.build, "csr": csr.build}


def build_spmv(v: Variant) -> Tuple[Callable, tuple]:
    """(fn, example_args) computing y = A @ x for the variant's format."""
    return _BUILDERS[v.fmt](v)


def build_sptrsv(v: Variant) -> Tuple[Callable, tuple]:
    """(fn, example_args) solving T x = b over the variant's triangle.

    CSR lowers the level-scheduled Pallas sweep; the padded column
    formats lower the dense fallback (see ``kernels/sptrsv.py``). The
    triangle side rides in the ``lo`` extra.
    """
    return sptrsv.build(v)


def build_symgs(v: Variant) -> Tuple[Callable, tuple]:
    """(fn, example_args) computing one symmetric Gauss-Seidel sweep."""
    return symgs.build(v)


def build_spmm(v: Variant) -> Tuple[Callable, tuple]:
    """(fn, example_args) computing Y = A @ X for an ``ncols > 1`` variant.

    X is ``(ncols, cols)`` — one input vector per row, so a coalesced
    serving batch marshals into a single contiguous literal and the whole
    batch executes in ONE kernel launch.
    """
    assert v.ncols > 1, f"SpMM variant needs ncols > 1, got {v.ncols} ({v.name})"
    return _BUILDERS[v.fmt](v)


def build_power_step(v: Variant) -> Tuple[Callable, tuple]:
    """One normalized power-iteration step: x' = A x / ||A x||_2.

    Demonstrates an L1 kernel composed into a larger L2 graph (the paper's
    motivating iterative-solver use case, §7.5): the SpMV product, the
    norm, and the scale all fuse into a single HLO module.
    """
    spmv, example = build_spmv(v)

    def fn(*args):
        (y,) = spmv(*args)
        nrm = jnp.sqrt(jnp.sum(y * y) + 1e-30)
        return (y / nrm,)

    return fn, example


# ---------------------------------------------------------------------------
# Default artifact inventory
# ---------------------------------------------------------------------------

def default_variants(quick: bool = False) -> List[Variant]:
    """The artifact set ``make artifacts`` compiles.

    ``quick`` builds the minimal subset used by fast CI / integration tests.
    """
    vs: List[Variant] = []

    def add(*a, **kw):
        vs.append(Variant(*a, **kw))

    # --- ELL: the richest knob space (all three x placements) -------------
    ell_buckets = [(256, 256, 16)] if quick else [(256, 256, 16), (1024, 1024, 16)]
    for (r, c, w) in ell_buckets:
        brs = [64] if quick else [64, 256]
        cws = [8] if quick else [8, 16]
        places = ["resident"] if quick else ["resident", "gather", "streamed"]
        for br in brs:
            for cw in cws:
                for p in places:
                    extra = (("xseg", c // 4),) if p == "streamed" else ()
                    add("ell", r, c, w, br, cw, p, extra=extra)

    # --- SELL: slice heights 8 and 32 --------------------------------------
    if not quick:
        for h in (8, 32):
            for cw in (8, 16):
                for p in ("resident", "gather"):
                    add("sell", 1024, 1024, 16, 8, cw, p, extra=(("h", h),))
    else:
        add("sell", 256, 256, 16, 8, 8, "resident", extra=(("h", 8),))

    # --- BELL: 8x8 MXU-aligned blocks --------------------------------------
    if not quick:
        for br in (4, 16):
            for p in ("resident", "gather"):
                add("bell", 1024, 1024, 16, br, 4, p, extra=(("bh", 8), ("bw", 8)))
    else:
        add("bell", 256, 256, 8, 4, 4, "resident", extra=(("bh", 8), ("bw", 8)))

    # --- CSR: nnz-chunked scatter kernel ------------------------------------
    if not quick:
        for nnz in (8192,):
            for cw in (1024, 2048):
                for p in ("resident", "gather"):
                    add("csr", 1024, 1024, nnz, 0, cw, p)
        add("csr", 256, 256, 2048, 0, 512, "resident")
    else:
        add("csr", 256, 256, 2048, 0, 512, "resident")

    return vs


def spmm_variants(quick: bool = False) -> List[Variant]:
    """The SpMM (multi-vector) artifact set ``make artifacts`` compiles.

    Batch buckets are the run-time chunking grain: a coalesced batch of k
    requests executes in ``ceil(k / ncols)`` launches against the largest
    bucket, vectors padded with zero rows up to the bucket. Kept separate
    from :func:`default_variants` so the SpMV inventory (and its tests)
    are untouched; ``aot.py`` emits these as ``kind=spmm`` manifest rows.

    Like the SpMV inventory, the SpMM set is swept across the compile
    knobs ``knob_map`` distinguishes (block_rows x chunk_width x
    x placement), so the runtime's joint (format, knob) decisions can
    re-select SpMM artifacts on a knob hot-swap, not just SpMV ones.
    The ``streamed`` placement has no SpMM lowering (the kernels reject
    it); ``knob_map``'s prefer-shared preference degrades to the nearest
    compiled placement through the selector's knob-break cost.
    """
    vs: List[Variant] = []

    def add(*a, **kw):
        vs.append(Variant(*a, **kw))

    if quick:
        # minimal CI subset, with one knob alternative so selection
        # knob-breaks are exercised end to end
        add("ell", 256, 256, 16, 64, 8, "resident", ncols=8)
        add("ell", 256, 256, 16, 64, 8, "gather", ncols=8)
        add("csr", 256, 256, 2048, 0, 512, "resident", ncols=8)
        return vs

    places = ("resident", "gather")  # streamed: no SpMM lowering
    for k in (4, 16):
        for br in (64, 256):
            for cw in (8, 16):
                for p in places:
                    add("ell", 1024, 1024, 16, br, cw, p, ncols=k)
        for cw in (8, 16):
            for p in places:
                add("sell", 1024, 1024, 16, 8, cw, p, ncols=k, extra=(("h", 8),))
        for br in (4, 16):
            for p in places:
                add("bell", 1024, 1024, 16, br, 4, p, ncols=k,
                    extra=(("bh", 8), ("bw", 8)))
        for p in places:
            add("csr", 1024, 1024, 8192, 0, 1024, p, ncols=k)
    # small-bucket knob pair so sub-256 matrices also batch (and still
    # have a placement alternative to knob-break between)
    add("ell", 256, 256, 16, 64, 8, "resident", ncols=8)
    add("ell", 256, 256, 16, 64, 8, "gather", ncols=8)
    add("csr", 256, 256, 2048, 0, 512, "resident", ncols=8)
    return vs


def sptrsv_variants(quick: bool = False) -> List[Variant]:
    """The SpTRSV artifact set ``make artifacts`` compiles.

    Reuses the SpMV knob grid's bucket and knob names so the runtime's
    joint (format, knob) decisions select solve artifacts through the
    same ``knob_map`` path. Every grid point is emitted for BOTH
    triangle sides (``lo=1`` lower, ``lo=0`` upper) — an upper solve
    must never silently fall back to a lower artifact.
    """
    vs: List[Variant] = []

    def add(*a, **kw):
        vs.append(Variant(*a, **kw))

    for lo in ((("lo", 1),), (("lo", 0),)):
        if quick:
            add("csr", 256, 256, 2048, 0, 512, "resident", extra=lo)
            add("ell", 256, 256, 16, 64, 8, "resident", extra=lo)
            continue
        for cw in (512, 1024):
            add("csr", 1024, 1024, 8192, 0, cw, "resident", extra=lo)
        add("csr", 256, 256, 2048, 0, 512, "resident", extra=lo)
        # dense fallbacks for the converted formats
        add("ell", 1024, 1024, 16, 64, 8, "resident", extra=lo)
        add("sell", 1024, 1024, 16, 8, 8, "resident", extra=(("h", 8),) + lo)
        add("bell", 1024, 1024, 16, 4, 4, "resident",
            extra=(("bh", 8), ("bw", 8)) + lo)
    return vs


def symgs_variants(quick: bool = False) -> List[Variant]:
    """The SymGS artifact set ``make artifacts`` compiles.

    A sweep is side-free (forward + backward in one graph), so there is
    no ``lo`` axis; one dense-fallback artifact per format keeps the
    per-format selection uniform with the other kernel classes.
    """
    vs: List[Variant] = []

    def add(*a, **kw):
        vs.append(Variant(*a, **kw))

    if quick:
        add("csr", 256, 256, 2048, 0, 512, "resident")
        add("ell", 256, 256, 16, 64, 8, "resident")
        return vs
    for cw in (512, 1024):
        add("csr", 1024, 1024, 8192, 0, cw, "resident")
    add("csr", 256, 256, 2048, 0, 512, "resident")
    add("ell", 1024, 1024, 16, 64, 8, "resident")
    add("sell", 1024, 1024, 16, 8, 8, "resident", extra=(("h", 8),))
    add("bell", 1024, 1024, 16, 4, 4, "resident", extra=(("bh", 8), ("bw", 8)))
    return vs


def power_step_variants(quick: bool = False) -> List[Variant]:
    """Variants additionally compiled as power-iteration-step artifacts."""
    del quick
    return [Variant("ell", 256, 256, 16, 64, 8, "resident")]
